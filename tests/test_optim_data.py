"""Optimizers, schedules, synthetic data determinism, R-SVD baseline."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import make_lowrank
from repro.configs.base import OptimConfig
from repro.core.rsvd import rsvd
from repro.data.synthetic import (LMBatchSpec, lm_batch, make_rsl_dataset,
                                  rsl_batch)
from repro.optim import make_optimizer, make_schedule
from repro.optim.optimizers import clip_by_global_norm, global_norm


@pytest.mark.parametrize("name", ["adamw", "sgd"])
def test_optimizer_converges_quadratic(name):
    cfg = OptimConfig(name=name, lr=0.1 if name == "adamw" else 0.05,
                      warmup_steps=0, total_steps=200, weight_decay=0.0,
                      schedule="constant", grad_clip=1e9)
    init, update = make_optimizer(cfg)
    target = {"w": jnp.asarray([1.0, -2.0, 3.0]), "b": jnp.asarray(0.5)}
    params = jax.tree.map(jnp.zeros_like, target)
    state = init(params)
    for _ in range(200):
        grads = jax.tree.map(lambda p, t: p - t, params, target)
        params, state, stats = update(params, state, grads)
    err = max(float(jnp.max(jnp.abs(p - t)))
              for p, t in zip(jax.tree.leaves(params),
                              jax.tree.leaves(target)))
    assert err < 1e-2


def test_weight_decay_decoupled():
    cfg = OptimConfig(name="adamw", lr=0.1, warmup_steps=0,
                      weight_decay=0.5, schedule="constant")
    init, update = make_optimizer(cfg)
    params = {"w": jnp.ones((4,))}
    state = init(params)
    zeros = {"w": jnp.zeros((4,))}
    params, state, _ = update(params, state, zeros)
    assert float(params["w"][0]) < 1.0     # decay applied with zero grads


def test_schedule_warmup_cosine():
    cfg = OptimConfig(lr=1.0, warmup_steps=10, total_steps=110,
                      schedule="cosine")
    s = make_schedule(cfg)
    assert float(s(0)) == pytest.approx(0.1)
    assert float(s(9)) == pytest.approx(1.0)
    assert float(s(10)) == pytest.approx(1.0, abs=1e-3)
    assert float(s(110)) == pytest.approx(0.0, abs=1e-6)
    assert float(s(60)) == pytest.approx(0.5, abs=0.01)


def test_grad_clip():
    g = {"a": jnp.full((4,), 10.0)}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert float(global_norm(clipped)) == pytest.approx(1.0, rel=1e-5)
    assert float(norm) == pytest.approx(20.0)


def test_lm_batch_deterministic():
    spec = LMBatchSpec(4, 32, 1000)
    b1 = lm_batch(spec, seed=7, step=3)
    b2 = lm_batch(spec, seed=7, step=3)
    b3 = lm_batch(spec, seed=7, step=4)
    np.testing.assert_array_equal(np.asarray(b1["tokens"]),
                                  np.asarray(b2["tokens"]))
    assert not np.array_equal(np.asarray(b1["tokens"]),
                              np.asarray(b3["tokens"]))
    # next-token structure
    np.testing.assert_array_equal(np.asarray(b1["tokens"][:, 1:]),
                                  np.asarray(b1["labels"][:, :-1]))


def test_rsl_dataset_learnable():
    ds = make_rsl_dataset(jax.random.PRNGKey(0), 256, 20, 24, 3, noise=0.0)
    assert set(np.unique(np.asarray(ds.y))) <= {-1.0, 1.0}
    # planted metric separates the data perfectly at zero noise
    score = jnp.einsum("nd,de,ne->n", ds.X, ds.W_true, ds.V)
    assert float((jnp.sign(score) == ds.y).mean()) == 1.0
    b = rsl_batch(ds, 0, 0, 32)
    assert b["x"].shape == (32, 20) and b["v"].shape == (32, 24)


def test_rsvd_with_oversampling_recovers(rng):
    """Oversampled R-SVD is accurate (paper's 'oversampled' column)."""
    A = make_lowrank(rng, 200, 150, 30)
    out = rsvd(A, 10, p=40, power_iters=2)
    s_true = jnp.linalg.svd(A, compute_uv=False)[:10]
    np.testing.assert_allclose(np.asarray(out.s), np.asarray(s_true),
                               rtol=1e-3)
