"""Gradient-rank telemetry (Alg 3 as a training-health metric)."""
import jax
import jax.numpy as jnp
import numpy as np

from conftest import make_lowrank
from repro.configs import get_arch
from repro.configs.base import FsvdConfig
from repro.models import model as M
from repro.runtime.telemetry import grad_spectrum, gradient_rank_summary


def test_grad_spectrum_lowrank(rng):
    g = make_lowrank(rng, 300, 200, 5)
    out = grad_spectrum(g, k=12)
    assert int(out["rank"]) == 5
    s_true = jnp.linalg.svd(g, compute_uv=False)[:5]
    np.testing.assert_allclose(np.asarray(out["sigma"][:5]),
                               np.asarray(s_true), rtol=1e-3)
    assert float(out["energy_r"]) > 0.999   # rank-5 captures everything


def test_grad_spectrum_full_rank(rng):
    g = jax.random.normal(rng, (128, 96))
    out = grad_spectrum(g, k=8)
    assert int(out["rank"]) == 8            # >= k Ritz values above tol
    assert float(out["energy_r"]) < 0.9     # white spectrum: top-8 is partial


def test_grad_spectrum_zero_gradient():
    """A dead layer (all-zero gradient) reports rank 0 and energy 0 —
    not NaN from a 0/0 energy ratio."""
    out = grad_spectrum(jnp.zeros((64, 48)), k=8)
    assert int(out["rank"]) == 0
    assert float(out["energy_r"]) == 0.0
    assert bool(jnp.all(jnp.isfinite(out["sigma"])))


def test_grad_spectrum_rank_clamped_to_k(rng):
    """Regression: numerical rank above the probe width must clamp to k —
    ``rank`` indexes the k-vector ``sigma``, so kprime > k would read out
    of bounds (or report a rank the sketch never certified)."""
    g = make_lowrank(rng, 96, 72, 8)        # true rank 8, probed with k=4
    out = grad_spectrum(g, k=4)
    assert int(out["rank"]) == 4
    assert out["sigma"].shape == (4,)
    assert 0.0 < float(out["energy_r"]) <= 1.0


def test_summary_on_model_grads():
    cfg = get_arch("stablelm-1.6b").reduced()
    params, _ = M.init_model(cfg, jax.random.PRNGKey(0))
    tok = jax.random.randint(jax.random.PRNGKey(1), (2, 32), 0,
                             cfg.vocab_size)
    batch = {"tokens": tok, "labels": jnp.roll(tok, -1, 1)}
    grads = jax.grad(lambda p: M.loss_fn(p, batch, cfg)[0])(params)
    summary = gradient_rank_summary(
        grads, FsvdConfig(compression_min_dim=64), k=8, max_leaves=4)
    assert len(summary) >= 1
    for name, s in summary.items():
        assert s["sigma"].shape == (8,)
        assert bool(jnp.all(jnp.isfinite(s["sigma"])))
        assert 0 <= int(s["rank"]) <= 8
