"""Gradient-rank telemetry (Alg 3 as a training-health metric)."""
import jax
import jax.numpy as jnp
import numpy as np

from conftest import make_lowrank
from repro.configs import get_arch
from repro.configs.base import FsvdConfig
from repro.models import model as M
from repro.runtime.telemetry import grad_spectrum, gradient_rank_summary


def test_grad_spectrum_lowrank(rng):
    g = make_lowrank(rng, 300, 200, 5)
    out = grad_spectrum(g, k=12)
    assert int(out["rank"]) == 5
    s_true = jnp.linalg.svd(g, compute_uv=False)[:5]
    np.testing.assert_allclose(np.asarray(out["sigma"][:5]),
                               np.asarray(s_true), rtol=1e-3)
    assert float(out["energy_r"]) > 0.999   # rank-5 captures everything


def test_grad_spectrum_full_rank(rng):
    g = jax.random.normal(rng, (128, 96))
    out = grad_spectrum(g, k=8)
    assert int(out["rank"]) == 8            # >= k Ritz values above tol
    assert float(out["energy_r"]) < 0.9     # white spectrum: top-8 is partial


def test_summary_on_model_grads():
    cfg = get_arch("stablelm-1.6b").reduced()
    params, _ = M.init_model(cfg, jax.random.PRNGKey(0))
    tok = jax.random.randint(jax.random.PRNGKey(1), (2, 32), 0,
                             cfg.vocab_size)
    batch = {"tokens": tok, "labels": jnp.roll(tok, -1, 1)}
    grads = jax.grad(lambda p: M.loss_fn(p, batch, cfg)[0])(params)
    summary = gradient_rank_summary(
        grads, FsvdConfig(compression_min_dim=64), k=8, max_leaves=4)
    assert len(summary) >= 1
    for name, s in summary.items():
        assert s["sigma"].shape == (8,)
        assert bool(jnp.all(jnp.isfinite(s["sigma"])))
        assert 0 <= int(s["rank"]) <= 8
