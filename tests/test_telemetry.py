"""Gradient-rank telemetry (Alg 3 as a training-health metric)."""
import jax
import jax.numpy as jnp
import numpy as np

from conftest import make_lowrank
from repro.configs import get_arch
from repro.configs.base import FsvdConfig
from repro.models import model as M
from repro.runtime.telemetry import grad_spectrum, gradient_rank_summary


def test_grad_spectrum_lowrank(rng):
    g = make_lowrank(rng, 300, 200, 5)
    out = grad_spectrum(g, k=12)
    assert int(out["rank"]) == 5
    s_true = jnp.linalg.svd(g, compute_uv=False)[:5]
    np.testing.assert_allclose(np.asarray(out["sigma"][:5]),
                               np.asarray(s_true), rtol=1e-3)
    assert float(out["energy_r"]) > 0.999   # rank-5 captures everything


def test_grad_spectrum_full_rank(rng):
    g = jax.random.normal(rng, (128, 96))
    out = grad_spectrum(g, k=8)
    assert int(out["rank"]) == 8            # >= k Ritz values above tol
    assert float(out["energy_r"]) < 0.9     # white spectrum: top-8 is partial


def test_grad_spectrum_zero_gradient():
    """A dead layer (all-zero gradient) reports rank 0 and energy 0 —
    not NaN from a 0/0 energy ratio."""
    out = grad_spectrum(jnp.zeros((64, 48)), k=8)
    assert int(out["rank"]) == 0
    assert float(out["energy_r"]) == 0.0
    assert bool(jnp.all(jnp.isfinite(out["sigma"])))


def test_grad_spectrum_rank_clamped_to_k(rng):
    """Regression: numerical rank above the probe width must clamp to k —
    ``rank`` indexes the k-vector ``sigma``, so kprime > k would read out
    of bounds (or report a rank the sketch never certified)."""
    g = make_lowrank(rng, 96, 72, 8)        # true rank 8, probed with k=4
    out = grad_spectrum(g, k=4)
    assert int(out["rank"]) == 4
    assert out["sigma"].shape == (4,)
    assert 0.0 < float(out["energy_r"]) <= 1.0


def test_summary_on_model_grads():
    cfg = get_arch("stablelm-1.6b").reduced()
    params, _ = M.init_model(cfg, jax.random.PRNGKey(0))
    tok = jax.random.randint(jax.random.PRNGKey(1), (2, 32), 0,
                             cfg.vocab_size)
    batch = {"tokens": tok, "labels": jnp.roll(tok, -1, 1)}
    grads = jax.grad(lambda p: M.loss_fn(p, batch, cfg)[0])(params)
    summary = gradient_rank_summary(
        grads, FsvdConfig(compression_min_dim=64), k=8, max_leaves=4)
    assert len(summary) >= 1
    for name, s in summary.items():
        assert s["sigma"].shape == (8,)
        assert bool(jnp.all(jnp.isfinite(s["sigma"])))
        assert 0 <= int(s["rank"]) <= 8


def test_latency_stats_reader_does_not_block_recorders(monkeypatch):
    """Regression: percentile()/summary() used to run np.percentile over
    the whole window while holding the lock record() needs on the
    dispatch hot path.  Park a reader inside a slow percentile and prove
    records still land while it is stuck."""
    import threading
    import time

    from repro.runtime import telemetry as T

    stats = T.LatencyStats(window=256)
    for i in range(64):
        stats.record(float(i))

    in_percentile = threading.Event()
    release = threading.Event()
    real_percentile = np.percentile

    def slow_percentile(data, p, *args, **kwargs):
        in_percentile.set()
        assert release.wait(timeout=10.0), "recorder never released reader"
        return real_percentile(data, p, *args, **kwargs)

    monkeypatch.setattr(T.np, "percentile", slow_percentile)
    out = {}
    reader = threading.Thread(
        target=lambda: out.setdefault("summary", stats.summary()))
    reader.start()
    try:
        assert in_percentile.wait(timeout=10.0)
        # reader is parked mid-percentile: the hot path must not care
        t0 = time.monotonic()
        for i in range(32):
            stats.record(1000.0 + i)
        elapsed = time.monotonic() - t0
        assert stats.count == 96          # records landed while parked
        assert elapsed < 5.0              # and never waited on the reader
    finally:
        release.set()
        reader.join(timeout=10.0)
    assert not reader.is_alive()
    # the reader's snapshot predates the concurrent records
    assert out["summary"]["count"] == 64
    assert out["summary"]["max_ms"] == 63.0
