"""Sketch-reconstruct vs tracked refine vs cold factorize on entry drift.

The PR 10 acceptance bench: a stream of *unstructured* drifts — per step
``nnz`` COO entry updates of fixed relative Frobenius mass, the regime no
low-rank factor pair can express (so the PR 7 update path is out of
reach).  Three arms solve the identical stream:

* **cold** — per-step ``factorize`` of the drifted operand (full Krylov
  budget; shares the plan compile cache, so the comparison isolates
  algorithmic cost).
* **refine** — ``Session`` with ``sketch_tol=0.0``: the sketch path
  disabled, so every entry batch folds into the operand and runs the
  warm-started refine solve (reduced GK budget) — the pre-PR-10 best.
* **sketch** — ``Session`` with a pinned ``sketch_tol``: entry batches
  fold into the resident sketch pair through the count-sketch
  scatter-add kernel and the answer is reconstructed from the panels —
  **zero** GK iterations, O(nnz·ζ + (m+n)k²) per step — accepted only
  when the HMT residual probe passes the gate (every served answer is
  probe-verified; rejected/stale steps fall back to a real solve and are
  counted).

All three arms are held to the same accuracy gate (max singular-value
error vs dense SVD of the true drifted matrix), so ``sketch < refine <
cold`` is a like-for-like wall-time claim.

Section schema ``sketchres/v1`` (validated by ``benchmarks.reanalyze``):
records carry raw timings/iterations/accept counts and the re-derivable
ratios ``sketch_vs_refine``/``sketch_vs_cold``/``refine_vs_cold``.

    PYTHONPATH=src python -m benchmarks.sketchres_bench
    PYTHONPATH=src python -m benchmarks.run --only sketchres --emit-json \
        BENCH_pr10.json
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import fmt_table, make_lowrank
from repro.api import Session, SVDSpec, clear_plan_cache, factorize

SIZES = [(512, 384, 8), (1024, 512, 16)]
QUICK_SIZES = [(256, 160, 8)]

STEPS = 8          # entry-drift steps per sweep
NNZ = 2048         # COO entries per step
DRIFT = 1e-3       # per-step relative (Frobenius) drift mass
SKETCH_TOL = 2e-2  # pinned probe gate — the parity bar all arms meet


def _entry_stream(key, m: int, n: int, r: int, steps: int, nnz: int,
                  drift: float):
    """Exactly rank-r A_0, then ``steps`` cumulative COO entry batches.

    Returns (operands, batches): ``operands[t+1]`` is ``operands[t]``
    with ``batches[t]`` scattered in — the cold/refine arms consume the
    operands, the sketch arm consumes only the triplets.
    """
    A = np.asarray(make_lowrank(key, m, n, r))
    rng = np.random.default_rng(int(jax.random.randint(
        jax.random.fold_in(key, 1), (), 0, 2**31 - 1)))
    operands, batches = [jnp.asarray(A)], []
    for _ in range(steps):
        rows = rng.integers(0, m, nnz).astype(np.int32)
        cols = rng.integers(0, n, nnz).astype(np.int32)
        vals = rng.standard_normal(nnz).astype(np.float32)
        vals *= drift * np.linalg.norm(A) / max(np.linalg.norm(vals), 1e-30)
        A = A.copy()
        np.add.at(A, (rows, cols), vals)
        batches.append((jnp.asarray(rows), jnp.asarray(cols),
                        jnp.asarray(vals)))
        operands.append(jnp.asarray(A))
    return ([jax.device_put(x) for x in operands],
            [tuple(jax.device_put(x) for x in b) for b in batches])


def _accuracy(fact, s_true) -> float:
    return float(jnp.max(jnp.abs(fact.s - s_true[: fact.rank]))
                 / s_true[0])


def _cold_sweep(operands, s_true, spec, key):
    """(total_ms, mean_iters, worst_err) for per-step cold factorize."""
    facts = []
    t0 = time.perf_counter()
    for t, A in enumerate(operands):
        f = factorize(A, spec, key=jax.random.fold_in(key, t))
        jax.block_until_ready(f.s)
        facts.append(f)
    ms = (time.perf_counter() - t0) * 1e3
    iters = sum(int(f.iterations) for f in facts) / len(facts)
    err = max(_accuracy(f, s) for f, s in zip(facts, s_true))
    return ms, iters, err


def _session_sweep(operands, batches, s_true, spec, key, sketch_tol):
    """One Session over the stream: solve A_0 cold, then one entries()
    per step.  ``sketch_tol=0.0`` pins the refine arm (sketch disabled);
    a positive gate lets the probe-verified reconstruct path engage."""
    sess = Session(operands[0], spec, key=key, track_residuals=False,
                   sketch_tol=sketch_tol)
    facts = []
    t0 = time.perf_counter()
    f = sess.solve()
    jax.block_until_ready(f.s)
    facts.append(f)
    for rows, cols, vals in batches:
        f = sess.entries(rows, cols, vals)
        jax.block_until_ready(f.s)
        facts.append(f)
    ms = (time.perf_counter() - t0) * 1e3
    iters = sum(r["iterations"] for r in sess.history) / len(sess.history)
    err = max(_accuracy(f, s) for f, s in zip(facts, s_true))
    probes = [r["probe"] for r in sess.history if r.get("kind") == "sketch"]
    return ms, iters, err, sess.counts(), probes


def run(sizes=None, repeats: int = 3, steps: int = STEPS,
        nnz: int = NNZ, drift: float = DRIFT) -> dict:
    key = jax.random.PRNGKey(10)
    records = []
    for m, n, r in (sizes or SIZES):
        spec = SVDSpec(method="fsvd", rank=r)
        operands, batches = _entry_stream(jax.random.fold_in(key, m * n),
                                          m, n, r, steps, nnz, drift)
        s_true = [jnp.linalg.svd(A, compute_uv=False) for A in operands]
        # one uncounted warm sweep per arm stages every executable (cold
        # budget, refine budget, sketch + fold + reconstruct) — the
        # measurement then isolates steady-state stream cost.
        _cold_sweep(operands[:2], s_true[:2], spec, key)
        _session_sweep(operands[:3], batches[:2], s_true[:3], spec, key,
                       0.0)
        _session_sweep(operands[:3], batches[:2], s_true[:3], spec, key,
                       SKETCH_TOL)
        cold_runs, refine_runs, sketch_runs = [], [], []
        for rep in range(repeats):
            cold_runs.append(_cold_sweep(
                operands, s_true, spec, jax.random.fold_in(key, rep)))
            refine_runs.append(_session_sweep(
                operands, batches, s_true, spec,
                jax.random.fold_in(key, 100 + rep), 0.0))
            sketch_runs.append(_session_sweep(
                operands, batches, s_true, spec,
                jax.random.fold_in(key, 200 + rep), SKETCH_TOL))
        cold_ms, cold_iters, cold_err = \
            sorted(cold_runs)[len(cold_runs) // 2]
        refine_ms, refine_iters, refine_err, _, _ = sorted(
            refine_runs, key=lambda x: x[0])[len(refine_runs) // 2]
        sketch_ms, sketch_iters, sketch_err, counts, probes = sorted(
            sketch_runs, key=lambda x: x[0])[len(sketch_runs) // 2]
        records.append({
            "m": m, "n": n, "rank": r, "steps": steps, "nnz": nnz,
            "drift": drift, "gate": SKETCH_TOL,
            "cold_ms": cold_ms, "refine_ms": refine_ms,
            "sketch_ms": sketch_ms,
            "cold_iters": cold_iters, "refine_iters": refine_iters,
            "sketch_iters": sketch_iters,
            "cold_err": cold_err, "refine_err": refine_err,
            "sketch_err": sketch_err,
            "sketch_accepts": counts.get("sketch", 0),
            "max_probe": max(probes) if probes else None,
            "sketch_vs_refine": refine_ms / sketch_ms,
            "sketch_vs_cold": cold_ms / sketch_ms,
            "refine_vs_cold": cold_ms / refine_ms,
        })
    rows = [[f"{r['m']}x{r['n']}", r["rank"], r["steps"], r["nnz"],
             f"{r['cold_ms']:.1f}", f"{r['refine_ms']:.1f}",
             f"{r['sketch_ms']:.1f}", f"{r['sketch_accepts']}/{r['steps']}",
             f"{r['sketch_vs_refine']:.2f}x",
             f"{r['sketch_vs_cold']:.2f}x",
             f"{r['cold_err']:.1e}", f"{r['sketch_err']:.1e}"]
            for r in records]
    print(fmt_table(["shape", "r", "steps", "nnz", "cold ms", "refine ms",
                     "sketch ms", "accepted", "skt/refine", "skt/cold",
                     "cold err", "sketch err"], rows))
    clear_plan_cache()
    return {"schema": "sketchres/v1", "records": records}


if __name__ == "__main__":
    run()
