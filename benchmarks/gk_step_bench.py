"""GK inner-loop step benchmark: fused pipeline vs unfused composition.

Measures one full left GK half-iteration update — the unit the solver
repeats k times — in two implementations:

  * ``unfused``  (the seed inner loop): separate ``matvec_fused`` and
    ``reorth`` kernel launches with the candidate vector round-tripping
    HBM between them, a jnp norm, and the whole-buffer masked carry
    ``jnp.where(keep, Q.at[:, i].set(qn), Q)`` — O(mk) traffic per step.
  * ``fused``    (this PR): the ``kernels.gk_step`` pipeline (matvec +
    CGS products + norm epilogue in ``passes+1`` passes over Q, candidate
    VMEM-resident) and the masked per-*column* carry — O(m) per step.

Both run at f32 and with bf16 basis/matrix storage (the mixed-precision
policy: half the bytes on every bandwidth-bound stream, f32 accumulate).
Kernel-only times (no carry) are reported alongside so the two effects
are separable.  Emit machine-readable records via ``benchmarks.run
--only gk_step --emit-json`` (schema ``gk_step/v1``, see README).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from benchmarks.common import fmt_table, timeit
from repro.kernels import ops

SIZES = [(2048, 512, 64), (4096, 512, 128), (8192, 512, 256)]
QUICK_SIZES = [(256, 128, 16)]
PASSES = 2
DTYPES = ("f32", "bf16")


@functools.partial(jax.jit, static_argnames=("passes",))
def _fused_step(A, p, y, alpha, Q, i, passes=PASSES):
    """Fused kernels + masked per-column carry (the new inner loop)."""
    u, beta = ops.gk_step_fused(A, p, y, alpha, Q, passes)
    qn = u / jnp.where(beta > 0, beta, 1.0)
    keep = beta > 1e-6
    cur = jax.lax.dynamic_slice_in_dim(Q, i, 1, axis=1)
    new = jnp.where(keep, qn.astype(Q.dtype)[:, None], cur)
    return jax.lax.dynamic_update_slice_in_dim(Q, new, i, axis=1), beta


@functools.partial(jax.jit, static_argnames=("passes",))
def _unfused_step(A, p, y, alpha, Q, i, passes=PASSES):
    """Seed inner loop: separate kernels + whole-buffer masked carry."""
    u = ops.matvec_fused(A, p, y, alpha)
    u = ops.reorth(u, Q, passes)
    beta = jnp.linalg.norm(u)
    qn = u / jnp.where(beta > 0, beta, 1.0)
    keep = beta > 1e-6
    return jnp.where(keep, Q.at[:, i].set(qn.astype(Q.dtype)), Q), beta


@functools.partial(jax.jit, static_argnames=("passes",))
def _unfused_kernels(A, p, y, alpha, Q, passes=PASSES):
    """Kernel composition only (no carry) — isolates the fusion win."""
    u = ops.reorth(ops.matvec_fused(A, p, y, alpha), Q, passes)
    return u, jnp.linalg.norm(u)


def _inputs(m, n, k, dtype_tag):
    ks = jax.random.split(jax.random.PRNGKey(m + n + k), 4)
    store = jnp.bfloat16 if dtype_tag == "bf16" else jnp.float32
    A = jax.random.normal(ks[0], (m, n)).astype(store)
    p = jax.random.normal(ks[1], (n,))
    y = jax.random.normal(ks[2], (m,))
    Q = jnp.linalg.qr(jax.random.normal(ks[3], (m, k)))[0].astype(store)
    return A, p, y, Q


def run(sizes=None, repeats: int = 3, dtypes=DTYPES) -> dict:
    sizes = SIZES if sizes is None else sizes
    records = []
    rows = []
    for (m, n, k) in sizes:
        for dt in dtypes:
            A, p, y, Q = _inputs(m, n, k, dt)
            i = jnp.asarray(k // 2, jnp.int32)
            tf, _ = timeit(_fused_step, A, p, y, 0.3, Q, i,
                           repeats=repeats)
            tu, _ = timeit(_unfused_step, A, p, y, 0.3, Q, i,
                           repeats=repeats)
            tfk, _ = timeit(ops.gk_step_fused, A, p, y, 0.3, Q, PASSES,
                            repeats=repeats)
            tuk, _ = timeit(_unfused_kernels, A, p, y, 0.3, Q,
                            repeats=repeats)
            rec = {"m": m, "n": n, "k": k, "dtype": dt, "passes": PASSES,
                   "fused_ms": tf * 1e3, "unfused_ms": tu * 1e3,
                   "speedup": tu / tf,
                   "fused_kernel_ms": tfk * 1e3,
                   "unfused_kernel_ms": tuk * 1e3,
                   "kernel_speedup": tuk / tfk}
            records.append(rec)
            rows.append([f"{m}x{n} k={k}", dt, f"{tu*1e3:.2f}",
                         f"{tf*1e3:.2f}", f"{rec['speedup']:.2f}x",
                         f"{rec['kernel_speedup']:.2f}x"])
    print("\n## GK step: fused pipeline vs unfused composition "
          "(ms per iteration step)")
    print(fmt_table(["shape", "store", "unfused", "fused", "step speedup",
                     "kernel speedup"], rows))
    return {"schema": "gk_step/v1",
            "backend": jax.default_backend(),
            "interpret": jax.default_backend() != "tpu",
            "passes": PASSES,
            "records": records}


if __name__ == "__main__":
    run()
