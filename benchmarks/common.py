"""Shared benchmark plumbing: timing, synthetic inputs, CSV/markdown out."""
from __future__ import annotations

import time
from typing import Callable

import jax
import jax.numpy as jnp


def make_lowrank(key, m: int, n: int, rank: int, dtype=jnp.float32):
    """The paper's synthetic input (§6.1): A = M @ N, Gaussian factors."""
    k1, k2 = jax.random.split(key)
    M = jax.random.normal(k1, (m, rank), dtype)
    N = jax.random.normal(k2, (rank, n), dtype)
    return M @ N


def timeit(fn: Callable, *args, repeats: int = 3, warmup: int = 1,
           **kw) -> tuple[float, object]:
    """Median wall time over ``repeats`` (paper: mean of 5; median is more
    robust at CPU-CI scale).  Blocks on the result."""
    out = None
    for _ in range(warmup):
        out = fn(*args, **kw)
        jax.block_until_ready(out)
    times = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = fn(*args, **kw)
        jax.block_until_ready(out)
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2], out


def fmt_table(headers: list[str], rows: list[list]) -> str:
    w = [max(len(str(h)), *(len(str(r[i])) for r in rows)) if rows
         else len(str(h)) for i, h in enumerate(headers)]
    out = [" | ".join(str(h).ljust(w[i]) for i, h in enumerate(headers))]
    out.append("-|-".join("-" * x for x in w))
    for r in rows:
        out.append(" | ".join(str(c).ljust(w[i]) for i, c in enumerate(r)))
    return "\n".join(out)
