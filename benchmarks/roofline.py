"""Roofline table from the dry-run artifacts (EXPERIMENTS.md §Roofline).

Terms per (arch x shape x mesh), all in seconds-per-step on TPU v5e:

    compute    = HLO_FLOPs_per_device / 197e12          (bf16 MXU peak)
    memory     = HLO_bytes_per_device / 819e9           (HBM bandwidth)
    collective = collective_bytes_per_device / 50e9     (ICI, per-link model:
                 all axes of the 2-D/3-D torus share the 4-link budget; we
                 charge the sum of per-device collective payload against one
                 50 GB/s link — a conservative single-link model)

plus MODEL_FLOPS = 6·N_active·D (2·N·D fwd-only) and the usefulness ratio
MODEL_FLOPS / (HLO_FLOPs x devices) — remat/redundancy waste shows up here.
"""
from __future__ import annotations

import json
import os
from typing import Optional

from benchmarks.common import fmt_table

PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # bytes/s / chip
LINK_BW = 50e9               # bytes/s / link (ICI)


def load_records(art_dir: str = "artifacts/dryrun") -> list[dict]:
    recs = []
    if not os.path.isdir(art_dir):
        return recs
    for name in sorted(os.listdir(art_dir)):
        if name.endswith(".json"):
            with open(os.path.join(art_dir, name)) as f:
                recs.append(json.load(f))
    return recs


def terms(rec: dict) -> Optional[dict]:
    if rec.get("status") != "ok":
        return None
    comp = rec["flops_per_device"] / PEAK_FLOPS
    memb = rec["bytes_per_device"] / HBM_BW
    coll = rec["collectives"]["total_bytes"] / LINK_BW
    dominant = max(("compute", comp), ("memory", memb),
                   ("collective", coll), key=lambda kv: kv[1])[0]
    hlo_total = rec["flops_per_device"] * rec["devices"]
    useful = rec["model_flops_global"] / hlo_total if hlo_total > 0 else 0.0
    # roofline fraction: model-useful compute time over the dominating term
    t_star = rec["model_flops_global"] / (rec["devices"] * PEAK_FLOPS)
    frac = t_star / max(comp, memb, coll) if max(comp, memb, coll) > 0 else 0
    return {"compute_s": comp, "memory_s": memb, "collective_s": coll,
            "dominant": dominant, "useful_ratio": useful,
            "roofline_frac": frac}


def run(art_dir: str = "artifacts/dryrun", mesh: str = "pod16x16") -> dict:
    recs = [r for r in load_records(art_dir) if r.get("mesh") == mesh]
    rows = []
    for r in recs:
        t = terms(r)
        if t is None:
            rows.append([r["arch"], r["shape"], "skip",
                         r.get("reason", r.get("error", ""))[:40], "", "",
                         "", ""])
            continue
        rows.append([
            r["arch"], r["shape"], t["dominant"],
            f"{t['compute_s']*1e3:.1f}", f"{t['memory_s']*1e3:.1f}",
            f"{t['collective_s']*1e3:.1f}",
            f"{t['useful_ratio']*100:.0f}%",
            f"{t['roofline_frac']*100:.1f}%"])
    print(f"\n## Roofline — {mesh} (ms per step; dominant term = bottleneck)")
    print(fmt_table(["arch", "shape", "bottleneck", "compute ms",
                     "memory ms", "collective ms", "useful flops",
                     "roofline frac"], rows))
    return {"roofline": rows}


if __name__ == "__main__":
    import sys
    run(mesh=sys.argv[1] if len(sys.argv) > 1 else "pod16x16")
