"""Chaos battery: the PR 6 Zipf traffic replay under fault injection.

The reliability question the serve layer must answer before the ROADMAP's
"production serving" north star means anything: when the dispatch worker
crashes, hangs, and the solver throws transient faults *while traffic is
running*, does the server (a) terminate every request with a result, a
labeled degraded result, or a typed error — no deadlocks, no silently
lost tickets; (b) keep availability (answered within deadline) at or
above the 99% target; and (c) certify every degraded answer it returns —
the HMT residual probe gate, cross-checked here against true singular
values at a fixed accuracy gate?

Each record replays the same synthetic stream under one fault mix
(``repro.runtime.faults.chaos``: per-dispatch crash/hang probabilities +
per-solve transient-fault probability), with a couple of deliberately
NaN-poisoned operands mixed in to exercise the submit-time quarantine.
The driver is ``launch.solve_serve.run_traffic`` — the same closed-loop
client pool the CLI uses, retrying typed-retryable failures
(``WorkerCrashed``, backpressure) up to 3 attempts, which is exactly the
client contract the failure taxonomy promises.

Section schema ``chaos/v1`` (validated by ``benchmarks.reanalyze``):
records carry raw counts and the re-derivable ``availability`` =
ok / (requests - quarantined - rejected), ``degraded_fraction`` =
degraded / ok and ``all_terminated`` = outcomes summing to requests.

    PYTHONPATH=src python -m benchmarks.chaos_bench
    PYTHONPATH=src python -m benchmarks.run --only chaos --emit-json \
        BENCH_pr8.json
"""
from __future__ import annotations

import time

import jax
import numpy as np

from benchmarks.common import fmt_table
from repro.api import SVDSpec, clear_plan_cache
from repro.launch.solve_serve import run_traffic
from repro.runtime import faults
from repro.serve import SolveServer
from repro.serve.traffic import DEFAULT_SHAPES, synthetic_stream

REQUESTS = 160
QUICK_REQUESTS = 60
RANK = 8
ZIPF_A = 1.1
TENANTS = 4
TENANT_FRACTION = 0.25
CLIENTS = 4
DEADLINE_MS = 15000.0     # generous: availability measures fault handling,
                          # not queueing at this offered load
POISONED = 2              # NaN operands mixed into every replay
AVAILABILITY_TARGET = 0.99
SIGMA_GATE = 0.05         # degraded answers: max rel sigma error allowed

# (label, {crash, hang, transient}): per-dispatch worker-crash/hang and
# per-solve transient-fault probabilities for the chaos context.
MIXES = [
    ("baseline", {"crash": 0.00, "hang": 0.00, "transient": 0.00}),
    ("faulty", {"crash": 0.03, "hang": 0.01, "transient": 0.05}),
    ("storm", {"crash": 0.10, "hang": 0.03, "transient": 0.15}),
]
QUICK_MIXES = MIXES[:2]


def _poison(reqs, n: int):
    """NaN-poison the operands of ``n`` anonymous factorize requests (in
    place on copies) — they must be quarantined at submit, not served."""
    poisoned = 0
    for r in reqs:
        if poisoned >= n:
            break
        if r.tenant is None and r.kind == "factorize":
            A = np.array(r.A, copy=True)
            A[0, 0] = np.nan
            r.A = A
            poisoned += 1
    return poisoned


def _sigma_err(fact, A) -> float:
    s_true = np.linalg.svd(np.asarray(A), compute_uv=False)
    s_true = s_true[: np.asarray(fact.s).shape[-1]]
    return float(np.max(np.abs(np.asarray(fact.s) - s_true)) / s_true[0])


def run(requests: int = REQUESTS, mixes=None, *, rank: int = RANK,
        seed: int = 0) -> dict:
    key = jax.random.key(4321)
    records = []
    for label, mix in (mixes or MIXES):
        reqs = list(synthetic_stream(
            requests, shapes=DEFAULT_SHAPES, zipf_a=ZIPF_A, rank=rank,
            tenants=TENANTS, tenant_fraction=TENANT_FRACTION, seed=7))
        n_poisoned = _poison(reqs, POISONED)

        spec = SVDSpec(method="fsvd", rank=rank)
        server = SolveServer(spec, max_batch=8, window_ms=2.0,
                             max_queue=4 * requests + 16, key=key,
                             hang_timeout_s=1.0, breaker_threshold=5,
                             breaker_reset_s=1.0, max_retries=2,
                             retry_backoff_ms=5.0)
        degraded_meta = []          # (probe, sigma_err) per degraded answer
        sampled_full = []           # sigma errs of non-degraded answers

        def collect(req, outcome, detail):
            if outcome != "ok" or req.tenant is not None \
                    or req.kind != "factorize":
                return
            err = _sigma_err(detail.value, req.A)
            if detail.meta.get("degraded"):
                degraded_meta.append((detail.meta["probe"], err))
            elif len(sampled_full) < 16:
                sampled_full.append(err)

        try:
            # warmup outside the fault window: compiles are deploy-time,
            # and a 1s hang watchdog must not misread an XLA compile.
            server.warmup(DEFAULT_SHAPES)
            # hang_s > hang_timeout_s: an injected hang must overrun the
            # watchdog, or it would measure as latency instead of a
            # detected-and-recovered worker hang.
            with faults.chaos(seed, dispatch_crash_p=mix["crash"],
                              dispatch_hang_p=mix["hang"], hang_s=2.5,
                              solve_transient_p=mix["transient"]):
                t0 = time.perf_counter()
                counts = run_traffic(
                    server, reqs, clients=CLIENTS,
                    timeout=DEADLINE_MS / 1e3, deadline_ms=DEADLINE_MS,
                    on_result=collect)
                wall_s = time.perf_counter() - t0
            faults.disarm_all()
            stats = server.stats()
        finally:
            faults.disarm_all()
            server.close()

        outcomes = (counts["ok"] + counts["rejected"] + counts["failed"]
                    + counts["timeouts"])
        quarantined = counts["errors"].get("PoisonedOperand", 0)
        eligible = max(requests - quarantined - counts["rejected"], 1)
        rec = {
            "mix": label, "requests": requests, "rank": rank,
            "crash_p": mix["crash"], "hang_p": mix["hang"],
            "transient_p": mix["transient"],
            "deadline_ms": DEADLINE_MS, "clients": CLIENTS,
            "poisoned": n_poisoned, "wall_s": wall_s,
            "ok": counts["ok"], "degraded": counts["degraded"],
            "rejected": counts["rejected"], "failed": counts["failed"],
            "timeouts": counts["timeouts"], "errors": counts["errors"],
            "quarantined": quarantined,
            "p50_ms": stats["latency_ms"]["p50_ms"],
            "p99_ms": stats["latency_ms"]["p99_ms"],
            "worker_restarts": stats["worker_restarts"],
            "worker_crashes": stats["worker_crashes"],
            "deadline_drops": stats["deadline_drops"],
            "retries": stats["retries"],
            "degraded_rejected": stats["degraded_rejected"],
            "breaker_open_shed": stats["breaker_open_shed"],
            "probe_gate": server.degraded_tol,
            "probe_max": max((p for p, _ in degraded_meta), default=0.0),
            "sigma_gate": SIGMA_GATE,
            "degraded_err_max": max((e for _, e in degraded_meta),
                                    default=0.0),
            "full_err_max": max(sampled_full, default=0.0),
        }
        rec["availability"] = counts["ok"] / eligible
        rec["degraded_fraction"] = (counts["degraded"] / counts["ok"]
                                    if counts["ok"] else 0.0)
        rec["all_terminated"] = outcomes == requests
        rec["availability_target"] = AVAILABILITY_TARGET
        rec["pass"] = (rec["all_terminated"]
                       and rec["availability"] >= AVAILABILITY_TARGET
                       and rec["quarantined"] == n_poisoned
                       and rec["degraded_err_max"] <= SIGMA_GATE)
        records.append(rec)

    rows = [[r["mix"], r["requests"],
             f"{r['availability']:.3f}", f"{r['degraded_fraction']:.3f}",
             f"{r['p99_ms']:.0f}", r["worker_restarts"], r["retries"],
             r["quarantined"], f"{r['degraded_err_max']:.1e}",
             "yes" if r["all_terminated"] else "NO",
             "PASS" if r["pass"] else "FAIL"]
            for r in records]
    print(fmt_table(["mix", "reqs", "avail", "degraded", "p99 ms",
                     "restarts", "retries", "quar", "deg err", "drained",
                     "gate"], rows))
    clear_plan_cache()
    return {"schema": "chaos/v1", "records": records}


if __name__ == "__main__":
    run()
