"""Cold ``factorize`` vs tracked ``Session.update`` on a drifting operator.

The paper's §V workload: a stream of partial SVDs of an operator that
drifts slowly between solves.  The cold baseline re-solves every step with
the full Krylov budget (but *does* share the plan compile cache — the
comparison isolates the algorithmic saving, not retrace overhead); the
tracked path warm-starts each solve from the previous Ritz basis with the
session's reduced refine budget.  Both must hit the same accuracy gate
(max singular-value error vs dense SVD), so the speedup is a like-for-like
iterations saving.

Section schema ``session/v1`` (validated by ``benchmarks.reanalyze``):
records carry raw timings/iterations and the re-derivable ``speedup`` =
cold_ms / tracked_ms and ``iter_ratio`` = cold_iters / tracked_iters.

    PYTHONPATH=src python -m benchmarks.session_bench
    PYTHONPATH=src python -m benchmarks.run --only session --emit-json \
        BENCH_pr5.json
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from benchmarks.common import fmt_table, make_lowrank
from repro.api import SVDSpec, Session, clear_plan_cache, factorize

SIZES = [(512, 384, 8), (1024, 512, 16), (2048, 1024, 16)]
QUICK_SIZES = [(256, 160, 8)]

STEPS = 8          # drift steps per sweep
DRIFT = 1e-3       # per-step relative (Frobenius) drift


def _drift_sequence(key, m: int, n: int, r: int, steps: int,
                    drift: float) -> list:
    """A_0 low-rank + noise, then ``steps`` cumulative relative drifts."""
    k0, kn, kd = jax.random.split(key, 3)
    A = make_lowrank(k0, m, n, r) \
        + 1e-4 * jax.random.normal(kn, (m, n))
    scale = float(jnp.linalg.norm(A)) * drift
    seq = [A]
    for t in range(steps):
        A = A + scale * jax.random.normal(jax.random.fold_in(kd, t),
                                          (m, n)) / jnp.sqrt(m * n)
        seq.append(A)
    return [jax.device_put(x) for x in seq]


def _accuracy(fact, A) -> float:
    s_true = jnp.linalg.svd(A, compute_uv=False)[: fact.rank]
    return float(jnp.max(jnp.abs(fact.s - s_true)) / s_true[0])


def _cold_sweep(seq, spec, key) -> tuple[float, float, float]:
    """(total_ms, mean_iters, worst_err) for per-step cold factorize."""
    facts = []
    t0 = time.perf_counter()
    for t, A in enumerate(seq):
        f = factorize(A, spec, key=jax.random.fold_in(key, t))
        jax.block_until_ready(f.s)
        facts.append(f)
    ms = (time.perf_counter() - t0) * 1e3
    iters = sum(int(f.iterations) for f in facts) / len(facts)
    err = max(_accuracy(f, A) for f, A in zip(facts, seq))
    return ms, iters, err


def _tracked_sweep(seq, spec, key) -> tuple[float, float, float, dict]:
    sess = Session(seq[0], spec, key=key, track_residuals=False)
    facts = []
    t0 = time.perf_counter()
    f = sess.solve()
    jax.block_until_ready(f.s)
    facts.append(f)
    for A in seq[1:]:
        f = sess.update(A)
        jax.block_until_ready(f.s)
        facts.append(f)
    ms = (time.perf_counter() - t0) * 1e3
    iters = sum(r["iterations"] for r in sess.history) / len(sess.history)
    err = max(_accuracy(f, A) for f, A in zip(facts, seq))
    return ms, iters, err, sess.counts()


def run(sizes=None, repeats: int = 3, steps: int = STEPS,
        drift: float = DRIFT) -> dict:
    key = jax.random.PRNGKey(42)
    records = []
    for m, n, r in (sizes or SIZES):
        spec = SVDSpec(method="fsvd", rank=r)
        seq = _drift_sequence(jax.random.fold_in(key, m * n), m, n, r,
                              steps, drift)
        # one uncounted warm sweep compiles both budgets into the plan
        # cache — the measurement then isolates solve cost.
        _cold_sweep(seq[:2], spec, key)
        _tracked_sweep(seq[:2], spec, key)
        cold_runs, tracked_runs = [], []
        for rep in range(repeats):
            cold_runs.append(_cold_sweep(seq, spec,
                                         jax.random.fold_in(key, rep)))
            tracked_runs.append(_tracked_sweep(
                seq, spec, jax.random.fold_in(key, 100 + rep)))
        cold_ms, cold_iters, cold_err = sorted(cold_runs)[len(cold_runs)//2]
        tracked_ms, tracked_iters, tracked_err, counts = sorted(
            tracked_runs, key=lambda x: x[0])[len(tracked_runs) // 2]
        records.append({
            "m": m, "n": n, "rank": r, "steps": steps, "drift": drift,
            "cold_ms": cold_ms, "tracked_ms": tracked_ms,
            "cold_iters": cold_iters, "tracked_iters": tracked_iters,
            "cold_err": cold_err, "tracked_err": tracked_err,
            "refines": counts["refine"], "restarts": counts["restart"],
            "speedup": cold_ms / tracked_ms,
            "iter_ratio": cold_iters / max(tracked_iters, 1e-9),
        })
    rows = [[f"{r['m']}x{r['n']}", r["rank"], r["steps"],
             f"{r['cold_ms']:.1f}", f"{r['tracked_ms']:.1f}",
             f"{r['speedup']:.2f}x",
             f"{r['cold_iters']:.0f}->{r['tracked_iters']:.1f}",
             f"{r['cold_err']:.1e}", f"{r['tracked_err']:.1e}"]
            for r in records]
    print(fmt_table(["shape", "r", "steps", "cold ms", "tracked ms",
                     "speedup", "GK iters", "cold err", "tracked err"],
                    rows))
    clear_plan_cache()
    return {"schema": "session/v1", "records": records}


if __name__ == "__main__":
    run()
