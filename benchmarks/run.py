"""Benchmark aggregator: one section per paper table/figure + roofline.

    PYTHONPATH=src python -m benchmarks.run            # everything
    PYTHONPATH=src python -m benchmarks.run --quick    # CI-sized subset
"""
from __future__ import annotations

import argparse
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="smaller sizes / fewer steps (CI)")
    ap.add_argument("--only", default=None,
                    choices=["table1", "table2", "fig1", "fig2", "roofline",
                             "kernels", "sparse"])
    args = ap.parse_args()

    from benchmarks import (fig1, fig2, kernels_bench, roofline, sparse_bench,
                            table1, table2)

    t0 = time.time()
    sections = []
    if args.only in (None, "table1"):
        sizes = table1.SIZES[:4] if args.quick else table1.SIZES
        sections.append(("table1", lambda: table1.run(sizes=sizes,
                                                      repeats=1 if args.quick
                                                      else 3)))
    if args.only in (None, "table2"):
        sizes2 = table2.SIZES[:2] if args.quick else table2.SIZES
        sections.append(("table2", lambda: table2.run(sizes=sizes2)))
    if args.only in (None, "fig1"):
        sections.append(("fig1", fig1.run))
    if args.only in (None, "fig2"):
        sections.append(("fig2", lambda: fig2.run(steps=40 if args.quick
                                                  else fig2.STEPS)))
    if args.only in (None, "kernels"):
        sections.append(("kernels", kernels_bench.run))
    if args.only in (None, "sparse"):
        sections.append(("sparse", lambda: sparse_bench.run(
            sizes=sparse_bench.SIZES[:1] if args.quick else None,
            repeats=1 if args.quick else 3)))
    if args.only in (None, "roofline"):
        sections.append(("roofline-single", lambda: roofline.run(
            mesh="pod16x16")))
        sections.append(("roofline-multi", lambda: roofline.run(
            mesh="pod2x16x16")))

    failures = []
    for name, fn in sections:
        print(f"\n{'='*72}\n# {name}\n{'='*72}")
        try:
            fn()
        except Exception as e:                      # noqa: BLE001
            failures.append((name, e))
            print(f"[bench] {name} FAILED: {e}")
    print(f"\n[bench] done in {time.time()-t0:.0f}s; "
          f"{len(sections)-len(failures)}/{len(sections)} sections ok")
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
