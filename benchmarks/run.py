"""Benchmark aggregator: one section per paper table/figure + roofline.

    PYTHONPATH=src python -m benchmarks.run            # everything
    PYTHONPATH=src python -m benchmarks.run --quick    # CI-sized subset
    PYTHONPATH=src python -m benchmarks.run --only gk_step --emit-json
                                                       # BENCH_pr3.json

``--emit-json [PATH]`` writes every section's machine-readable records to
one standardized json (default name ``BENCH_pr3.json``) so future PRs can
diff their speedups against a stored baseline:

    {"schema": "repro-bench/v1", "quick": bool, "backend": str,
     "sections": {<name>: <section dict, e.g. schema gk_step/v1>}}

``benchmarks.reanalyze`` validates/re-derives the file.
"""
from __future__ import annotations

import argparse
import json
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="smaller sizes / fewer steps (CI)")
    ap.add_argument("--only", default=None,
                    choices=["table1", "table2", "fig1", "fig2", "roofline",
                             "kernels", "sparse", "gk_step", "dist",
                             "session", "serve", "update", "chaos",
                             "sketch", "sketchres"])
    ap.add_argument("--emit-json", nargs="?", const="BENCH_pr3.json",
                    default=None, metavar="PATH",
                    help="write section records to a standardized BENCH "
                         "json (default PATH: BENCH_pr3.json; use --only "
                         "dist --emit-json BENCH_pr4.json for the device-"
                         "scaling artifact, --only session --emit-json "
                         "BENCH_pr5.json for the tracked-session one, "
                         "--only serve --emit-json BENCH_pr6.json for the "
                         "serve-traffic one, --only update --emit-json "
                         "BENCH_pr7.json for the rank-k-update one, "
                         "--only chaos --emit-json BENCH_pr8.json for the "
                         "fault-injection one, --only sketch --emit-json "
                         "BENCH_pr9.json for the sketch-solver frontier, "
                         "--only sketchres --emit-json BENCH_pr10.json "
                         "for the sketch-resident entry-drift one)")
    args = ap.parse_args()

    from benchmarks import (chaos_bench, dist_bench, fig1, fig2,
                            gk_step_bench, kernels_bench, roofline,
                            serve_bench, session_bench, sketch_bench,
                            sketchres_bench, sparse_bench, table1, table2,
                            update_bench)

    t0 = time.time()
    sections = []
    if args.only in (None, "table1"):
        sizes = table1.SIZES[:4] if args.quick else table1.SIZES
        sections.append(("table1", lambda: table1.run(sizes=sizes,
                                                      repeats=1 if args.quick
                                                      else 3)))
    if args.only in (None, "table2"):
        sizes2 = table2.SIZES[:2] if args.quick else table2.SIZES
        sections.append(("table2", lambda: table2.run(sizes=sizes2)))
    if args.only in (None, "fig1"):
        sections.append(("fig1", fig1.run))
    if args.only in (None, "fig2"):
        sections.append(("fig2", lambda: fig2.run(steps=40 if args.quick
                                                  else fig2.STEPS)))
    if args.only in (None, "kernels"):
        sections.append(("kernels", kernels_bench.run))
    if args.only in (None, "sparse"):
        sections.append(("sparse", lambda: sparse_bench.run(
            sizes=sparse_bench.SIZES[:1] if args.quick else None,
            repeats=1 if args.quick else 3)))
    if args.only in (None, "gk_step"):
        sections.append(("gk_step", lambda: gk_step_bench.run(
            sizes=gk_step_bench.QUICK_SIZES if args.quick else None,
            repeats=1 if args.quick else 3)))
    if args.only in (None, "dist"):
        sections.append(("dist", lambda: dist_bench.run(
            quick=args.quick,
            repeats=1 if args.quick else 3)))
    if args.only in (None, "session"):
        sections.append(("session", lambda: session_bench.run(
            sizes=session_bench.QUICK_SIZES if args.quick else None,
            repeats=1 if args.quick else 3,
            steps=4 if args.quick else session_bench.STEPS)))
    if args.only in (None, "update"):
        sections.append(("update", lambda: update_bench.run(
            sizes=update_bench.QUICK_SIZES if args.quick else None,
            repeats=1 if args.quick else 3,
            steps=4 if args.quick else update_bench.STEPS)))
    if args.only in (None, "chaos"):
        sections.append(("chaos", lambda: chaos_bench.run(
            requests=chaos_bench.QUICK_REQUESTS if args.quick
            else chaos_bench.REQUESTS,
            mixes=chaos_bench.QUICK_MIXES if args.quick else None)))
    if args.only in (None, "serve"):
        sections.append(("serve", lambda: serve_bench.run(
            requests=serve_bench.QUICK_REQUESTS if args.quick
            else serve_bench.REQUESTS,
            mixes=serve_bench.QUICK_MIXES if args.quick else None,
            repeats=1 if args.quick else 3)))
    if args.only in (None, "sketch"):
        sections.append(("sketch", lambda: sketch_bench.run(
            sizes=sketch_bench.QUICK_SIZES if args.quick else None,
            repeats=1 if args.quick else 3)))
    if args.only in (None, "sketchres"):
        sections.append(("sketchres", lambda: sketchres_bench.run(
            sizes=sketchres_bench.QUICK_SIZES if args.quick else None,
            repeats=1 if args.quick else 3,
            steps=4 if args.quick else sketchres_bench.STEPS,
            nnz=512 if args.quick else sketchres_bench.NNZ)))
    if args.only in (None, "roofline"):
        sections.append(("roofline-single", lambda: roofline.run(
            mesh="pod16x16")))
        sections.append(("roofline-multi", lambda: roofline.run(
            mesh="pod2x16x16")))

    failures = []
    results = {}
    for name, fn in sections:
        print(f"\n{'='*72}\n# {name}\n{'='*72}")
        try:
            out = fn()
            if isinstance(out, dict):
                results[name] = out
        except Exception as e:                      # noqa: BLE001
            failures.append((name, e))
            print(f"[bench] {name} FAILED: {e}")
    if args.emit_json:
        import jax
        payload = {"schema": "repro-bench/v1", "quick": args.quick,
                   "backend": jax.default_backend(), "sections": results}
        with open(args.emit_json, "w") as f:
            json.dump(payload, f, indent=1)
        print(f"[bench] wrote {args.emit_json} "
              f"({len(results)} section(s))")
    print(f"\n[bench] done in {time.time()-t0:.0f}s; "
          f"{len(sections)-len(failures)}/{len(sections)} sections ok")
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
