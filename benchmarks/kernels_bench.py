"""Micro-bench: Pallas-kernel wrappers vs jnp reference (CPU interpret mode
— correctness + dispatch overhead only; the real perf target is the
VMEM-tiled Mosaic build on TPU, whose cost model is in EXPERIMENTS.md)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import fmt_table, timeit
from repro.kernels import ops, ref


def run() -> dict:
    m, n, k = 2048, 1024, 64
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 6)
    A = jax.random.normal(ks[0], (m, n))
    p = jax.random.normal(ks[1], (n,))
    q = jax.random.normal(ks[2], (m,))
    Q = jnp.linalg.qr(jax.random.normal(ks[3], (m, k)))[0]
    U = jax.random.normal(ks[4], (m, k))
    s = jnp.abs(jax.random.normal(ks[5], (k,)))
    Vt = jax.random.normal(ks[0], (k, n))

    jit_ref = {
        "matvec_fused": jax.jit(ref.matvec_fused),
        "reorth": jax.jit(ref.reorth, static_argnames=("passes",)),
        "lowrank_matmul": jax.jit(ref.lowrank_matmul),
    }
    rows = []
    t, _ = timeit(ops.matvec_fused, A, p, q, 0.5)
    tr, _ = timeit(jit_ref["matvec_fused"], A, p, q, 0.5)
    rows.append(["matvec_fused (2048x1024)", f"{t*1e3:.2f}", f"{tr*1e3:.2f}"])
    t, _ = timeit(ops.reorth, q, Q, 2)
    tr, _ = timeit(jit_ref["reorth"], q, Q, 2)
    rows.append([f"reorth (2048x{k}, CGS2)", f"{t*1e3:.2f}", f"{tr*1e3:.2f}"])
    t, _ = timeit(ops.lowrank_matmul, U, s, Vt)
    tr, _ = timeit(jit_ref["lowrank_matmul"], U, s, Vt)
    rows.append([f"lowrank_matmul ({m}x{n} r={k})", f"{t*1e3:.2f}",
                 f"{tr*1e3:.2f}"])
    print("\n## Kernel micro-bench (ms; interpret mode on CPU)")
    print(fmt_table(["kernel", "pallas (interpret)", "jnp ref"], rows))
    return {"kernels": rows}


if __name__ == "__main__":
    run()
