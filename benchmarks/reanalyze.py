"""Re-derive roofline inputs for existing dry-run/hillclimb artifacts from
their saved (gzipped) HLO — lets analyzer fixes propagate without the 40-min
recompile sweep.

    PYTHONPATH=src python -m benchmarks.reanalyze artifacts/dryrun
"""
from __future__ import annotations

import gzip
import json
import os
import sys

from repro.launch import hlo_analysis


def reanalyze_dir(art_dir: str) -> int:
    hlo_dir = os.path.join(art_dir, "hlo")
    n = 0
    for name in sorted(os.listdir(art_dir)):
        if not name.endswith(".json"):
            continue
        path = os.path.join(art_dir, name)
        rec = json.load(open(path))
        if rec.get("status") != "ok":
            continue
        hlo_path = os.path.join(hlo_dir, name[:-5] + ".hlo.gz")
        if not os.path.exists(hlo_path):
            continue
        with gzip.open(hlo_path, "rt") as f:
            hc = hlo_analysis.analyze(f.read())
        rec["flops_per_device"] = hc.dot_flops
        rec["bytes_per_device"] = hc.hbm_bytes
        rec["collectives"] = {
            **{k: {"bytes": hc.collective_bytes[k],
                   "count": hc.collective_counts[k]}
               for k in hlo_analysis.COLLECTIVE_KINDS},
            "total_bytes": hc.total_collective_bytes,
        }
        with open(path, "w") as f:
            json.dump(rec, f, indent=1)
        n += 1
    return n


if __name__ == "__main__":
    for d in (sys.argv[1:] or ["artifacts/dryrun", "artifacts/hillclimb"]):
        if os.path.isdir(d):
            print(f"[reanalyze] {d}: {reanalyze_dir(d)} records updated")
