"""Re-derive analysis outputs for existing benchmark artifacts without
re-running the sweeps.

Three artifact kinds:

  * dry-run / hillclimb directories — recompute roofline inputs from the
    saved (gzipped) HLO, so analyzer fixes propagate without the 40-min
    recompile sweep.
  * standardized BENCH json (``repro-bench/v1``, e.g. ``BENCH_pr3.json``
    from ``benchmarks.run --emit-json``) — validate the schema and
    recompute every derived field (speedups) from the raw timings, so a
    hand-edited or schema-drifted file is caught in CI.
  * the cross-PR trajectory: ``--trajectory [DIR]`` stitches every
    ``BENCH_*.json`` under DIR (default: cwd) into one
    ``BENCH_trajectory.json`` + a markdown table — per PR artifact, per
    section, the headline metric (mean step speedup, best device scaling,
    tracked-session speedup) — so the perf history reads off one report
    instead of N per-PR files.

    PYTHONPATH=src python -m benchmarks.reanalyze artifacts/dryrun
    PYTHONPATH=src python -m benchmarks.reanalyze BENCH_pr3.json
    PYTHONPATH=src python -m benchmarks.reanalyze --trajectory .
"""
from __future__ import annotations

import gzip
import json
import os
import sys

from repro.launch import hlo_analysis


def reanalyze_dir(art_dir: str) -> int:
    hlo_dir = os.path.join(art_dir, "hlo")
    n = 0
    for name in sorted(os.listdir(art_dir)):
        if not name.endswith(".json"):
            continue
        path = os.path.join(art_dir, name)
        rec = json.load(open(path))
        if rec.get("status") != "ok":
            continue
        hlo_path = os.path.join(hlo_dir, name[:-5] + ".hlo.gz")
        if not os.path.exists(hlo_path):
            continue
        with gzip.open(hlo_path, "rt") as f:
            hc = hlo_analysis.analyze(f.read())
        rec["flops_per_device"] = hc.dot_flops
        rec["bytes_per_device"] = hc.hbm_bytes
        rec["collectives"] = {
            **{k: {"bytes": hc.collective_bytes[k],
                   "count": hc.collective_counts[k]}
               for k in hlo_analysis.COLLECTIVE_KINDS},
            "total_bytes": hc.total_collective_bytes,
        }
        with open(path, "w") as f:
            json.dump(rec, f, indent=1)
        n += 1
    return n


_GK_STEP_RAW = ("m", "n", "k", "dtype", "fused_ms", "unfused_ms",
                "fused_kernel_ms", "unfused_kernel_ms")

_DIST_RAW = ("devices", "m", "n", "k", "rank", "step_ms", "rstep_ms",
             "solve_ms")


def _check_dist_section(path: str, sec: dict) -> int:
    """Validate a ``dist/v1`` section: raw fields present (``devices`` is
    the scaling axis), and every ``*_vs_1dev`` ratio re-derivable from the
    raw timings of the devices==1 record of the same shape."""
    n = 0
    base = {}
    for r in sec["records"]:
        missing = [f for f in _DIST_RAW if f not in r]
        if missing:
            raise SystemExit(f"{path}: dist record missing {missing}")
        if not (isinstance(r["devices"], int) and r["devices"] >= 1):
            raise SystemExit(
                f"{path}: dist record has bad devices={r['devices']!r}")
        if r["devices"] == 1:
            base[(r["m"], r["n"], r["k"])] = r
    for r in sec["records"]:
        b = base.get((r["m"], r["n"], r["k"]))
        for field, num in (("step_vs_1dev", "step_ms"),
                           ("solve_vs_1dev", "solve_ms")):
            want = b[num] / r[num] if b else None
            have = r.get(field)
            if want is not None and have is not None \
                    and abs(have - want) > 1e-6 * want:
                raise SystemExit(
                    f"{path}: dist {r['m']}x{r['n']} devices={r['devices']}"
                    f": stored {field}={have:.4f} disagrees with raw "
                    f"timings ({want:.4f})")
            r[field] = want
        print(f"[reanalyze] dist {r['m']}x{r['n']} k={r['k']} "
              f"devices={r['devices']}: step {r['step_ms']:.2f}ms, "
              f"solve {r['solve_ms']:.1f}ms")
        n += 1
    return n


_SESSION_RAW = ("m", "n", "rank", "steps", "cold_ms", "tracked_ms",
                "cold_iters", "tracked_iters")


def _check_session_section(path: str, sec: dict) -> int:
    """Validate a ``session/v1`` section: raw cold-vs-tracked fields
    present, derived ``speedup`` / ``iter_ratio`` re-derivable."""
    n = 0
    for r in sec["records"]:
        missing = [f for f in _SESSION_RAW if f not in r]
        if missing:
            raise SystemExit(f"{path}: session record missing {missing}")
        for field, num, den in (("speedup", "cold_ms", "tracked_ms"),
                                ("iter_ratio", "cold_iters",
                                 "tracked_iters")):
            want = r[num] / max(r[den], 1e-9)
            have = r.get(field)
            if have is not None and abs(have - want) > 1e-6 * want:
                raise SystemExit(
                    f"{path}: session {r['m']}x{r['n']} r={r['rank']}: "
                    f"stored {field}={have:.4f} disagrees with raw "
                    f"values ({want:.4f})")
            r[field] = want
        print(f"[reanalyze] session {r['m']}x{r['n']} r={r['rank']} "
              f"steps={r['steps']}: {r['speedup']:.2f}x wall, "
              f"{r['iter_ratio']:.2f}x fewer GK iters")
        n += 1
    return n


_SERVE_RAW = ("requests", "rank", "batched_wall_ms", "unbatched_wall_ms",
              "batched_err", "unbatched_err", "tenant_iters", "cold_iters")


def _check_serve_section(path: str, sec: dict) -> int:
    """Validate a ``serve/v1`` section: raw batched-vs-unbatched traffic
    fields present; derived ``speedup`` / ``iter_ratio`` and both rps
    figures re-derivable from the raw walls."""
    n = 0
    for r in sec["records"]:
        missing = [f for f in _SERVE_RAW if f not in r]
        if missing:
            raise SystemExit(f"{path}: serve record missing {missing}")
        derived = (
            ("speedup", r["unbatched_wall_ms"] /
             max(r["batched_wall_ms"], 1e-9)),
            ("iter_ratio", r["cold_iters"] / max(r["tenant_iters"], 1e-9)),
            ("batched_rps", r["requests"] /
             max(r["batched_wall_ms"] / 1e3, 1e-9)),
            ("unbatched_rps", r["requests"] /
             max(r["unbatched_wall_ms"] / 1e3, 1e-9)),
        )
        for field, want in derived:
            have = r.get(field)
            if have is not None and abs(have - want) > 1e-6 * abs(want):
                raise SystemExit(
                    f"{path}: serve mix={r.get('mix')!r} "
                    f"requests={r['requests']}: stored {field}="
                    f"{have:.4f} disagrees with raw values ({want:.4f})")
            r[field] = want
        print(f"[reanalyze] serve mix={r.get('mix')!r} "
              f"requests={r['requests']} r={r['rank']}: "
              f"{r['speedup']:.2f}x throughput, "
              f"{r['iter_ratio']:.2f}x fewer tenant GK iters")
        n += 1
    return n


_CHAOS_RAW = ("mix", "requests", "crash_p", "hang_p", "transient_p",
              "deadline_ms", "ok", "degraded", "rejected", "failed",
              "timeouts", "quarantined", "poisoned", "p99_ms",
              "worker_restarts", "deadline_drops", "retries",
              "probe_gate", "sigma_gate", "degraded_err_max")


def _check_chaos_section(path: str, sec: dict) -> int:
    """Validate a ``chaos/v1`` section: raw fault-mix outcome counts
    present; derived ``availability`` / ``degraded_fraction`` /
    ``all_terminated`` re-derivable from them."""
    n = 0
    for r in sec["records"]:
        missing = [f for f in _CHAOS_RAW if f not in r]
        if missing:
            raise SystemExit(f"{path}: chaos record missing {missing}")
        eligible = max(r["requests"] - r["quarantined"] - r["rejected"], 1)
        outcomes = r["ok"] + r["rejected"] + r["failed"] + r["timeouts"]
        derived = (
            ("availability", r["ok"] / eligible),
            ("degraded_fraction",
             r["degraded"] / r["ok"] if r["ok"] else 0.0),
        )
        for field, want in derived:
            have = r.get(field)
            if have is not None and abs(have - want) > 1e-6 * max(want, 1.0):
                raise SystemExit(
                    f"{path}: chaos mix={r['mix']!r}: stored {field}="
                    f"{have:.4f} disagrees with raw counts ({want:.4f})")
            r[field] = want
        terminated = outcomes == r["requests"]
        if r.get("all_terminated") is not None \
                and bool(r["all_terminated"]) != terminated:
            raise SystemExit(
                f"{path}: chaos mix={r['mix']!r}: stored all_terminated="
                f"{r['all_terminated']} but outcomes sum to {outcomes} of "
                f"{r['requests']} requests")
        r["all_terminated"] = terminated
        print(f"[reanalyze] chaos mix={r['mix']!r} "
              f"requests={r['requests']} (crash={r['crash_p']:.2f} "
              f"hang={r['hang_p']:.2f} transient={r['transient_p']:.2f}): "
              f"availability {r['availability']:.3f}, "
              f"degraded {r['degraded_fraction']:.3f}, "
              f"restarts {r['worker_restarts']}, "
              f"drained={'yes' if terminated else 'NO'}")
        n += 1
    return n


_UPDATE_RAW = ("m", "n", "rank", "k_drift", "steps", "cold_ms",
               "refine_ms", "update_ms", "cold_iters", "refine_iters",
               "updates")


def _check_update_section(path: str, sec: dict) -> int:
    """Validate an ``update/v1`` section: raw three-arm (cold / refine /
    rank-k update) fields present, every stored speedup ratio
    re-derivable from the raw wall times."""
    n = 0
    for r in sec["records"]:
        missing = [f for f in _UPDATE_RAW if f not in r]
        if missing:
            raise SystemExit(f"{path}: update record missing {missing}")
        derived = (
            ("update_vs_refine", r["refine_ms"] /
             max(r["update_ms"], 1e-9)),
            ("update_vs_cold", r["cold_ms"] / max(r["update_ms"], 1e-9)),
            ("refine_vs_cold", r["cold_ms"] / max(r["refine_ms"], 1e-9)),
        )
        for field, want in derived:
            have = r.get(field)
            if have is not None and abs(have - want) > 1e-6 * abs(want):
                raise SystemExit(
                    f"{path}: update {r['m']}x{r['n']} r={r['rank']} "
                    f"k={r['k_drift']}: stored {field}={have:.4f} "
                    f"disagrees with raw timings ({want:.4f})")
            r[field] = want
        print(f"[reanalyze] update {r['m']}x{r['n']} r={r['rank']} "
              f"k={r['k_drift']} steps={r['steps']}: "
              f"{r['update_vs_refine']:.2f}x vs refine, "
              f"{r['update_vs_cold']:.2f}x vs cold "
              f"({r['updates']} zero-iteration updates)")
        n += 1
    return n


_SKETCH_RAW = ("m", "n", "rank", "method", "passes", "sweeps", "ms",
               "err_abs", "sigma_max")


def _check_sketch_section(path: str, sec: dict) -> int:
    """Validate a ``sketch/v1`` section: raw accuracy-vs-passes frontier
    fields present, the stored relative error re-derivable from the raw
    absolute error and σ_max."""
    n = 0
    for r in sec["records"]:
        missing = [f for f in _SKETCH_RAW if f not in r]
        if missing:
            raise SystemExit(f"{path}: sketch record missing {missing}")
        want = r["err_abs"] / max(r["sigma_max"], 1e-30)
        have = r.get("err_rel")
        if have is not None and abs(have - want) > 1e-6 * max(want, 1e-30):
            raise SystemExit(
                f"{path}: sketch {r['m']}x{r['n']} {r['method']} "
                f"passes={r['passes']}: stored err_rel={have:.4e} "
                f"disagrees with err_abs/sigma_max ({want:.4e})")
        r["err_rel"] = want
        print(f"[reanalyze] sketch {r['m']}x{r['n']} r={r['rank']} "
              f"{r['method']} passes={r['passes']} "
              f"sweeps={r['sweeps']}: rel err {r['err_rel']:.2e} "
              f"in {r['ms']:.2f} ms")
        n += 1
    return n


_SKETCHRES_RAW = ("m", "n", "rank", "steps", "nnz", "gate", "cold_ms",
                  "refine_ms", "sketch_ms", "cold_iters", "refine_iters",
                  "sketch_iters", "cold_err", "refine_err", "sketch_err",
                  "sketch_accepts")


def _check_sketchres_section(path: str, sec: dict) -> int:
    """Validate a ``sketchres/v1`` section: raw three-arm (cold / refine /
    sketch-reconstruct) entry-drift fields present, every stored speedup
    ratio re-derivable from the raw wall times, and every accepted
    reconstruction probe-verified (``max_probe <= gate`` — the invariant
    that no unverified answer was ever served)."""
    n = 0
    for r in sec["records"]:
        missing = [f for f in _SKETCHRES_RAW if f not in r]
        if missing:
            raise SystemExit(f"{path}: sketchres record missing {missing}")
        if r["sketch_accepts"] and r.get("max_probe") is not None \
                and r["max_probe"] > r["gate"]:
            raise SystemExit(
                f"{path}: sketchres {r['m']}x{r['n']}: accepted "
                f"reconstruction with probe {r['max_probe']:.3e} above "
                f"the gate {r['gate']:.3e} — unverified answer served")
        derived = (
            ("sketch_vs_refine", r["refine_ms"] /
             max(r["sketch_ms"], 1e-9)),
            ("sketch_vs_cold", r["cold_ms"] / max(r["sketch_ms"], 1e-9)),
            ("refine_vs_cold", r["cold_ms"] / max(r["refine_ms"], 1e-9)),
        )
        for field, want in derived:
            have = r.get(field)
            if have is not None and abs(have - want) > 1e-6 * abs(want):
                raise SystemExit(
                    f"{path}: sketchres {r['m']}x{r['n']} r={r['rank']} "
                    f"nnz={r['nnz']}: stored {field}={have:.4f} "
                    f"disagrees with raw timings ({want:.4f})")
            r[field] = want
        print(f"[reanalyze] sketchres {r['m']}x{r['n']} r={r['rank']} "
              f"steps={r['steps']} nnz={r['nnz']}: "
              f"{r['sketch_vs_refine']:.2f}x vs refine, "
              f"{r['sketch_vs_cold']:.2f}x vs cold "
              f"({r['sketch_accepts']} probe-verified zero-iteration "
              f"reconstructions)")
        n += 1
    return n


def reanalyze_bench(path: str) -> int:
    """Validate a ``repro-bench/v1`` file and recompute derived fields."""
    bench = json.load(open(path))
    if bench.get("schema") != "repro-bench/v1":
        raise SystemExit(f"{path}: not a repro-bench/v1 file "
                         f"(schema={bench.get('schema')!r})")
    n = 0
    for name, sec in sorted(bench.get("sections", {}).items()):
        schema = sec.get("schema")
        if schema == "gk_step/v1":
            for r in sec["records"]:
                missing = [f for f in _GK_STEP_RAW if f not in r]
                if missing:
                    raise SystemExit(
                        f"{path}: gk_step record missing {missing}")
                for field, num, den in (
                        ("speedup", "unfused_ms", "fused_ms"),
                        ("kernel_speedup", "unfused_kernel_ms",
                         "fused_kernel_ms")):
                    want = r[num] / r[den]
                    have = r.get(field)
                    if have is not None and abs(have - want) > 1e-6 * want:
                        raise SystemExit(
                            f"{path}: gk_step {r['m']}x{r['n']} k={r['k']} "
                            f"{r['dtype']}: stored {field}={have:.4f} "
                            f"disagrees with raw timings ({want:.4f})")
                    r[field] = want
                print(f"[reanalyze] gk_step {r['m']}x{r['n']} k={r['k']} "
                      f"{r['dtype']}: step {r['speedup']:.2f}x, "
                      f"kernels {r['kernel_speedup']:.2f}x")
                n += 1
        elif schema == "dist/v1":
            n += _check_dist_section(path, sec)
        elif schema == "session/v1":
            n += _check_session_section(path, sec)
        elif schema == "serve/v1":
            n += _check_serve_section(path, sec)
        elif schema == "update/v1":
            n += _check_update_section(path, sec)
        elif schema == "chaos/v1":
            n += _check_chaos_section(path, sec)
        elif schema == "sketch/v1":
            n += _check_sketch_section(path, sec)
        elif schema == "sketchres/v1":
            n += _check_sketchres_section(path, sec)
        else:
            # sections without derived fields (kernels, sparse, ...) are
            # carried as-is; an unknown schema is not an error, new
            # sections opt in here.
            print(f"[reanalyze] section {name!r}: schema {schema!r} "
                  "carried through")
    with open(path, "w") as f:
        json.dump(bench, f, indent=1)
    return n


# ---------------------------------------------------------------------------
# cross-PR trajectory
# ---------------------------------------------------------------------------

def _headline(schema, records) -> tuple[str, float]:
    """One (label, value) summary per section — the number a reader scans
    the trajectory for.  Empty sections report 0.0, never divide."""
    if schema == "gk_step/v1":
        sp = [r["unfused_ms"] / r["fused_ms"] for r in records]
        return "mean fused-step speedup", sum(sp) / len(sp) if sp else 0.0
    if schema == "dist/v1":
        scal = [r["solve_ms"] and (r.get("solve_vs_1dev") or 0.0)
                for r in records]
        return "best solve scaling vs 1 dev", max(scal) if scal else 0.0
    if schema == "session/v1":
        sp = [r["cold_ms"] / max(r["tracked_ms"], 1e-9) for r in records]
        return "mean tracked-session speedup", (sum(sp) / len(sp)
                                               if sp else 0.0)
    if schema == "serve/v1":
        sp = [r["unbatched_wall_ms"] / max(r["batched_wall_ms"], 1e-9)
              for r in records]
        return "mean batched-serving speedup", (sum(sp) / len(sp)
                                                if sp else 0.0)
    if schema == "update/v1":
        sp = [r["refine_ms"] / max(r["update_ms"], 1e-9) for r in records]
        return "mean update-vs-refine speedup", (sum(sp) / len(sp)
                                                if sp else 0.0)
    if schema == "chaos/v1":
        # the number that matters under faults: worst-mix availability
        av = [r["ok"] / max(r["requests"] - r["quarantined"]
                            - r["rejected"], 1) for r in records]
        return "worst-mix availability under faults", (min(av) if av
                                                       else 0.0)
    if schema == "sketch/v1":
        # the frontier's floor: what a SINGLE operator sweep costs in
        # accuracy (gnystrom's whole reason to exist)
        gny = [r["err_abs"] / max(r["sigma_max"], 1e-30)
               for r in records if r["method"] == "gnystrom"]
        return "worst single-pass rel err", max(gny) if gny else 0.0
    if schema == "sketchres/v1":
        sp = [r["refine_ms"] / max(r["sketch_ms"], 1e-9) for r in records]
        return "mean sketch-vs-refine speedup", (sum(sp) / len(sp)
                                                if sp else 0.0)
    return "records", float(len(records))


def build_trajectory(directory: str = ".") -> dict:
    """Aggregate every ``BENCH_*.json`` under ``directory`` into one
    cross-PR report (written as ``BENCH_trajectory.json``)."""
    entries = []
    names = sorted(n for n in os.listdir(directory)
                   if n.startswith("BENCH_") and n.endswith(".json")
                   and n != "BENCH_trajectory.json")
    for name in names:
        path = os.path.join(directory, name)
        try:
            bench = json.load(open(path))
        except json.JSONDecodeError as e:
            raise SystemExit(f"[trajectory] {path}: invalid json ({e})")
        if bench.get("schema") != "repro-bench/v1":
            print(f"[trajectory] {name}: not repro-bench/v1, skipped")
            continue
        sections = []
        for sec_name, sec in sorted(bench.get("sections", {}).items()):
            label, value = _headline(sec.get("schema"),
                                     sec.get("records", []))
            # backend rides on every section row, not just the artifact
            # envelope: a flat consumer of the report (plot a metric over
            # PRs, split by backend) gets a self-identifying record
            # without joining back through the artifact entry.
            sections.append({"section": sec_name,
                             "schema": sec.get("schema"),
                             "backend": bench.get("backend"),
                             "records": len(sec.get("records", [])),
                             "headline": label, "value": value})
        entries.append({"artifact": name, "backend": bench.get("backend"),
                        "quick": bench.get("quick"), "sections": sections})
    report = {"schema": "repro-bench-trajectory/v1", "entries": entries}
    out = os.path.join(directory, "BENCH_trajectory.json")
    with open(out, "w") as f:
        json.dump(report, f, indent=1)
    # the human-readable view
    print(f"\n[trajectory] {len(entries)} artifact(s) -> {out}")
    # backend is part of the row identity: a cpu-quick artifact and a
    # tpu one for the same PR must never read as one perf trajectory
    print(f"{'artifact':<18} {'backend':<8} {'section':<10} "
          f"{'schema':<12} {'headline':<30} value")
    for e in entries:
        for s in e["sections"]:
            print(f"{e['artifact']:<18} {str(e['backend']):<8} "
                  f"{s['section']:<10} {str(s['schema']):<12} "
                  f"{s['headline']:<30} {s['value']:.2f}")
    return report


if __name__ == "__main__":
    args = sys.argv[1:]
    if args and args[0] == "--trajectory":
        build_trajectory(args[1] if len(args) > 1 else ".")
        sys.exit(0)
    explicit = bool(args)
    for d in (args or ["artifacts/dryrun", "artifacts/hillclimb"]):
        if os.path.isfile(d) and d.endswith(".json"):
            print(f"[reanalyze] {d}: {reanalyze_bench(d)} records updated")
        elif os.path.isdir(d):
            print(f"[reanalyze] {d}: {reanalyze_dir(d)} records updated")
        elif explicit:
            # a validator that silently skips its input is no validator
            raise SystemExit(f"[reanalyze] {d}: no such file or directory")
