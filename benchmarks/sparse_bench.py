"""Sparse-vs-dense sweep: matrix-free `fsvd_blocked` on SparseOp vs dense
solvers on the materialized matrix.

The claim being measured: once A no longer fits as a dense (m, n) block —
or simply when nnz ≪ m·n — the streaming blocked solver wins on both memory
(basis capped at ``max_basis`` n-vectors) and wall time (matvec cost scales
with nnz, not m·n).  Sweeps density at fixed size and size at fixed
density, xla vs pallas sparse backends, with dense F-SVD / R-SVD anchors.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import fmt_table, timeit
from repro.api import SVDSpec, factorize
from repro.data.synthetic import make_sparse_problem

RANK = 10
SIZES = [(500, 400), (1000, 800), (2000, 1600)]
DENSITIES = [0.001, 0.01, 0.05]


def _err(out, dense) -> float:
    s_true = jnp.linalg.svd(dense, compute_uv=False)[:out.s.shape[0]]
    return float(jnp.max(jnp.abs(out.s - s_true))
                 / jnp.maximum(s_true[0], 1e-12))


def run(sizes=None, densities=None, repeats: int = 3) -> dict:
    sizes = sizes or SIZES
    densities = densities or DENSITIES
    key = jax.random.PRNGKey(0)
    solve_key = jax.random.PRNGKey(1)
    rows = []
    for m, n in sizes:
        for density in densities:
            key, kp = jax.random.split(key)
            prob = make_sparse_problem(kp, m, n, density=density)
            prob_pl = make_sparse_problem(kp, m, n, density=density,
                                          backend="pallas")
            blocked = SVDSpec(method="fsvd_blocked", rank=RANK)
            entries = [
                ("sparse/blocked/xla", prob.op, blocked),
                ("sparse/blocked/pallas", prob_pl.op, blocked),
                ("dense/fsvd", prob.dense,
                 SVDSpec(method="fsvd", rank=RANK)),
                ("dense/rsvd", prob.dense,
                 SVDSpec(method="rsvd", rank=RANK, power_iters=2)),
            ]
            for label, operand, spec in entries:
                t, out = timeit(
                    lambda op=operand, sp=spec: factorize(
                        op, sp, key=solve_key),
                    repeats=repeats)
                rows.append([f"{m}x{n}", density, prob.op.nnz, label,
                             f"{t * 1e3:.1f}", f"{_err(out, prob.dense):.1e}"])
    table = fmt_table(
        ["shape", "density", "nnz", "solver", "ms", "sigma err"], rows)
    print(table)
    return {"rows": rows, "table": table}


if __name__ == "__main__":
    run()
