"""Paper Table 2: residual + relative errors of the four SVD algorithms."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import fmt_table, make_lowrank
from repro.api import SVDSpec, factorize

SIZES = [(1000, 1000), (2000, 1000), (4000, 2000), (10000, 2000)]
RANK = 100
R_WANT = 20
KEY = jax.random.PRNGKey(0)


def _errors(A, U, s, V) -> tuple[float, float]:
    rel = float(jnp.linalg.norm(A.T @ U - V * s[None, :])
                / jnp.linalg.norm(s))
    res = float(jnp.linalg.norm(A - (U * s[None, :]) @ V.T))
    return res, rel


def run(sizes=SIZES, rank=RANK, r=R_WANT) -> dict:
    rows = []
    for m, n in sizes:
        A = make_lowrank(jax.random.PRNGKey(0), m, n, rank)
        Ud, sd, Vtd = jnp.linalg.svd(A, full_matrices=False)
        e_svd = _errors(A, Ud[:, :r], sd[:r], Vtd[:r].T)
        f = factorize(A, SVDSpec(method="fsvd", rank=r, max_iters=2 * rank,
                                 host_loop=True), key=KEY)
        e_f = _errors(A, f.U, f.s, f.V)
        ro = factorize(A, SVDSpec(method="rsvd", rank=r, oversample=rank,
                                  power_iters=2), key=KEY)
        e_ro = _errors(A, ro.U, ro.s, ro.V)
        rd = factorize(A, SVDSpec(method="rsvd", rank=r, oversample=10),
                       key=KEY)
        e_rd = _errors(A, rd.U, rd.s, rd.V)
        rows.append([f"{m}x{n}",
                     f"{e_svd[0]:.2e}", f"{e_svd[1]:.2e}",
                     f"{e_f[0]:.2e}", f"{e_f[1]:.2e}",
                     f"{e_ro[0]:.2e}", f"{e_ro[1]:.2e}",
                     f"{e_rd[0]:.2e}", f"{e_rd[1]:.2e}"])
    print("\n## Table 2 — residual ||A-USV'|| / relative ||A'U-VS||/||S|| "
          "errors (r=20 of rank-100 inputs: residual is Eckart-Young-bounded"
          " for ALL methods; the relative error separates them)")
    print(fmt_table(
        ["size", "SVD res", "SVD rel", "F-SVD res", "F-SVD rel",
         "R-SVD(over) res", "R-SVD(over) rel", "R-SVD(def) res",
         "R-SVD(def) rel"], rows))
    return {"table2": rows}


if __name__ == "__main__":
    run()
