"""Paper Tables 1a + 1b: rank-estimation and partial-SVD wall time.

CPU-feasible sizes (up to 2e4 x 2e3; the paper's 1e5-row largest cells are
reached through the distributed path, see DESIGN.md §6).  All inputs have
numerical rank 100 and we ask for the 20 dominant triplets, as in §6.2.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import fmt_table, make_lowrank, timeit
from repro.api import SVDSpec, estimate_rank, factorize
from repro.core.gk_block import fsvd_block

SIZES = [(1000, 1000), (2000, 1000), (5000, 1000), (4000, 2000),
         (10000, 2000), (20000, 2000)]
RANK = 100
R_WANT = 20
KEY = jax.random.PRNGKey(0)


def run(sizes=SIZES, rank=RANK, r=R_WANT, repeats=3) -> dict:
    rows_a, rows_b = [], []
    for m, n in sizes:
        A = make_lowrank(jax.random.PRNGKey(0), m, n, rank)

        # --- Table 1a: rank estimation ---
        t_svd_rank, s = timeit(
            lambda: jnp.linalg.svd(A, compute_uv=False), repeats=repeats)
        import time as _t
        t0 = _t.perf_counter()
        out = estimate_rank(A, max_iters=min(m, n), key=KEY)
        t_alg3 = _t.perf_counter() - t0
        rows_a.append([f"{m}x{n}", f"{t_svd_rank:.3f}", f"{t_alg3:.3f}",
                       int(out.iterations), int(out.rank)])

        # --- Table 1b: partial SVD (one facade, four specs) ---
        spec_f = SVDSpec(method="fsvd", rank=r, max_iters=2 * rank,
                         host_loop=True)
        spec_rd = SVDSpec(method="rsvd", rank=r, oversample=10)
        spec_ro = SVDSpec(method="rsvd", rank=r, oversample=rank,
                          power_iters=2)
        t_svd, _ = timeit(lambda: jnp.linalg.svd(A, full_matrices=False),
                          repeats=repeats)
        t_fsvd, fout = timeit(
            lambda: factorize(A, spec_f, key=KEY), repeats=repeats)
        t_rsvd_d, _ = timeit(
            lambda: jax.block_until_ready(factorize(A, spec_rd, key=KEY)),
            repeats=repeats)
        t_rsvd_o, _ = timeit(
            lambda: jax.block_until_ready(factorize(A, spec_ro, key=KEY)),
            repeats=repeats)
        # beyond-paper: block GK (b vectors per pass over A; see
        # core/gk_block.py) — same accuracy class as F-SVD, fewer A passes
        t_block, _ = timeit(
            lambda: jax.block_until_ready(
                fsvd_block(A, r, block=max(64, r), steps=4, key=KEY)),
            repeats=repeats)
        rows_b.append([f"{m}x{n}", f"{t_svd:.3f}", f"{t_fsvd:.3f}",
                       f"{t_block:.3f}", f"{t_rsvd_d:.3f}",
                       f"{t_rsvd_o:.3f}"])

    print("\n## Table 1a — rank estimation (seconds; rank detected)")
    print(fmt_table(
        ["size", "dense SVD", "Alg 3", "Alg1 iters", "rank found"], rows_a))
    print("\n## Table 1b — 20 dominant triplets (seconds)")
    print(fmt_table(
        ["size", "dense SVD", "F-SVD", "F-SVD block", "R-SVD (default)",
         "R-SVD (oversampled)"], rows_b))
    print(
        "\nNote: the sequential host-loop algorithms (Alg 1/3, vector F-SVD)"
        "\npay ~100 x the JAX per-op dispatch overhead on CPU — the paper's"
        "\nNumPy loops do not. The BLOCK variant (core/gk_block.py, ~4 passes"
        "\nover A) removes that overhead and restores the paper's wall-time"
        "\nordering vs dense SVD on this host; on TPU the same blocking is"
        "\nwhat feeds the MXU (DESIGN.md §3). Accuracy columns: Table 2.")
    return {"table1a": rows_a, "table1b": rows_b}


if __name__ == "__main__":
    run()
