"""Accuracy-vs-passes frontier: rbk / gnystrom vs rsvd / fsvd.

The PR 9 acceptance bench.  Every sketch solver is a point on one
trade-off curve — how much accuracy does each additional pass over the
operator buy?

* **gnystrom** — ONE operator sweep (both sketches captured together):
  the floor of the frontier; its error is the price of touching the
  data exactly once.
* **rbk** — block Krylov: 2·passes+1 sweeps, gap-independent gain per
  pass (the Musco–Musco guarantee).
* **rsvd** — HMT power iteration: 2·power_iters+2 sweeps, the classical
  baseline rbk must dominate at equal sweep count.
* **fsvd** — the GK bidiagonalization reference (iterative budget, not
  sweep-comparable — included as the accuracy ceiling).

All arms share the plan compile cache and are timed warm, so wall times
compare solve cost, not tracing.  Section schema ``sketch/v1``
(validated by ``benchmarks.reanalyze``): records carry the raw absolute
error and σ_max so the relative error is re-derivable.

    PYTHONPATH=src python -m benchmarks.sketch_bench
    PYTHONPATH=src python -m benchmarks.run --only sketch --emit-json \
        BENCH_pr9.json
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from benchmarks.common import fmt_table
from repro.api import SVDSpec, clear_plan_cache, factorize

SIZES = [(512, 384, 16), (1024, 512, 16)]
QUICK_SIZES = [(256, 160, 8)]

PASSES = (0, 1, 2, 3)      # rbk passes / rsvd power_iters sweep grid
DECAY = 0.85               # graded spectrum: σ_i = DECAY^i


def _graded_matrix(key, m: int, n: int, decay: float = DECAY):
    """Dense matrix with σ_i = decay^i — a spectrum where every extra
    pass is visible (neither flat nor trivially low-rank)."""
    k1, k2 = jax.random.split(key)
    d = min(m, n)
    U = jnp.linalg.qr(jax.random.normal(k1, (m, d)))[0]
    V = jnp.linalg.qr(jax.random.normal(k2, (n, d)))[0]
    return (U * (decay ** jnp.arange(d))[None, :]) @ V.T


def _sweeps(method: str, passes: int) -> int:
    """Operator sweeps actually performed (the x-axis of the frontier)."""
    if method == "gnystrom":
        return 1
    if method == "rbk":
        return 2 * passes + 1
    if method == "rsvd":
        return 2 * passes + 2       # sketch + final + 2 per power iter
    return -1                        # fsvd: iterative, not sweep-priced


def _time_arm(A, spec, key, repeats: int):
    """(median_ms, err_abs) — warm solve (first call stages, uncounted)."""
    f = factorize(A, spec, key=key)
    jax.block_until_ready(f.s)
    times = []
    for rep in range(repeats):
        t0 = time.perf_counter()
        f = factorize(A, spec, key=jax.random.fold_in(key, rep))
        jax.block_until_ready(f.s)
        times.append((time.perf_counter() - t0) * 1e3)
    return sorted(times)[len(times) // 2], f


def run(sizes=None, repeats: int = 3, passes=PASSES) -> dict:
    key = jax.random.PRNGKey(17)
    records = []
    for m, n, r in (sizes or SIZES):
        A = _graded_matrix(jax.random.fold_in(key, m * n), m, n)
        s_true = jnp.linalg.svd(A, compute_uv=False)
        smax = float(s_true[0])

        arms = [("gnystrom", 0, SVDSpec(method="gnystrom", rank=r)),
                ("fsvd", 0, SVDSpec(method="fsvd", rank=r))]
        for p in passes:
            arms.append(("rbk", p,
                         SVDSpec(method="rbk", rank=r, passes=p)))
            arms.append(("rsvd", p,
                         SVDSpec(method="rsvd", rank=r, power_iters=p)))

        for method, p, spec in arms:
            ms, f = _time_arm(A, spec, jax.random.fold_in(key, hash(
                (method, p)) % (1 << 31)), repeats)
            err = float(jnp.max(jnp.abs(f.s - s_true[:r])))
            records.append({
                "m": m, "n": n, "rank": r, "method": method,
                "passes": p, "sweeps": _sweeps(method, p), "ms": ms,
                "err_abs": err, "sigma_max": smax,
                "err_rel": err / smax,
            })
    rows = [[f"{r['m']}x{r['n']}", r["rank"], r["method"], r["passes"],
             r["sweeps"], f"{r['ms']:.2f}", f"{r['err_rel']:.2e}"]
            for r in records]
    print(fmt_table(["shape", "r", "method", "passes", "sweeps", "ms",
                     "rel err"], rows))
    clear_plan_cache()
    return {"schema": "sketch/v1", "records": records}


if __name__ == "__main__":
    run()
