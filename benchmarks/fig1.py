"""Paper Figure 1: triplet-quality diagnostics.

quality_i = |u_svd_i . u_alg_i| * |v_svd_i . v_alg_i|  (1.0 = perfect) and
sigma error = sigma_svd_i - sigma_alg_i, for the 100 dominant triplets of a
rank-1000 input (paper: 1e4x1e4, k=550, p=800; scaled to 2000x2000 for CPU
with the same rank/k/p *ratios*)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import fmt_table, make_lowrank
from repro.api import SVDSpec, factorize

M = N = 2000
RANK = 200        # paper ratio: rank = m/10
R_WANT = 100      # dominant triplets requested
K_FSVD = 110      # paper: k = 5.5 * r
P_OVER = 160      # paper: p = 8 * r... scaled: l = r + p

def run() -> dict:
    A = make_lowrank(jax.random.PRNGKey(0), M, N, RANK)
    Ud, sd, Vtd = jnp.linalg.svd(A, full_matrices=False)

    def quality(U, s, V, r):
        qu = np.abs(np.sum(np.asarray(Ud[:, :r]) * np.asarray(U[:, :r]), 0))
        qv = np.abs(np.sum(np.asarray(Vtd[:r].T) * np.asarray(V[:, :r]), 0))
        return qu * qv, np.asarray(sd[:r] - s[:r])

    key = jax.random.PRNGKey(0)
    f = factorize(A, SVDSpec(method="fsvd", rank=R_WANT,
                             max_iters=5 * R_WANT + 50, host_loop=True),
                  key=key)
    q_f, ds_f = quality(f.U, f.s, f.V, R_WANT)
    ro = factorize(A, SVDSpec(method="rsvd", rank=R_WANT, oversample=P_OVER,
                              power_iters=2), key=key)
    q_o, ds_o = quality(ro.U, ro.s, ro.V, R_WANT)
    rd = factorize(A, SVDSpec(method="rsvd", rank=R_WANT, oversample=10),
                   key=key)
    q_d, ds_d = quality(rd.U, rd.s, rd.V, R_WANT)

    rows = []
    for name, q, ds in [("F-SVD", q_f, ds_f),
                        ("R-SVD oversampled", q_o, ds_o),
                        ("R-SVD default", q_d, ds_d)]:
        rows.append([name, f"{q.min():.4f}", f"{np.median(q):.4f}",
                     f"{(q > 0.99).mean()*100:.0f}%",
                     f"{np.abs(ds).max():.2e}"])
    print("\n## Figure 1 — triplet quality vs dense SVD "
          f"(top {R_WANT} of a rank-{RANK} {M}x{N} input)")
    print(fmt_table(["method", "min quality", "median quality",
                     "% triplets >0.99", "max |sigma err|"], rows))
    return {"fig1": rows}


if __name__ == "__main__":
    run()
