"""Serve traffic simulation: continuous-batched server vs one-at-a-time
``factorize`` under a Zipf shape mix.

The serving question the ROADMAP's top item asks: does coalescing
same-shape requests into vmap-batched dispatch beat answering each request
individually — at equal accuracy?  Both paths share the process-wide plan
cache (compiles are warmed out of the measurement, steady-state serving is
the regime of interest); the comparison isolates the *batching* win:
fewer, fatter XLA dispatches instead of one per request.  The same run
ablates tenant tracking: repeat clients served through their Session
(warm-started refine budget) vs the cold solves the unbatched baseline
pays for the identical request sequence.

Section schema ``serve/v1`` (validated by ``benchmarks.reanalyze``):
records carry raw walls/iterations/errors and the re-derivable
``speedup`` = unbatched_wall_ms / batched_wall_ms and ``iter_ratio`` =
cold_iters / tenant_iters.

    PYTHONPATH=src python -m benchmarks.serve_bench
    PYTHONPATH=src python -m benchmarks.run --only serve --emit-json \
        BENCH_pr6.json
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from benchmarks.common import fmt_table
from repro.api import SVDSpec, clear_plan_cache, factorize
from repro.api.plan import plan as make_plan
from repro.serve import SolveServer
from repro.serve.traffic import DEFAULT_SHAPES, synthetic_stream

REQUESTS = 200
QUICK_REQUESTS = 60
ZIPF_A = 1.1
TENANTS = 4
TENANT_FRACTION = 0.25
MAX_BATCH = 8
WINDOW_MS = 4.0

# (label, shape menu): the stock serve mix plus a 4x-area mix where the
# batched GEMMs have more arithmetic to amortize into.
MIXES = [
    ("small", DEFAULT_SHAPES),
    ("medium", tuple((2 * m, 2 * n) for m, n in DEFAULT_SHAPES)),
]
QUICK_MIXES = [MIXES[0]]


def _warm(spec: SVDSpec, shapes, key) -> None:
    """Stage the sequential baseline's executables (one solve per shape);
    the server warms its own batched signatures via ``warmup``."""
    p = make_plan(spec)
    for s in shapes:
        zero = jnp.zeros(s, jnp.float32)
        jax.block_until_ready(p.solve(zero, key=key).s)


def _sigma_err(fact, A) -> float:
    s_true = jnp.linalg.svd(jnp.asarray(A), compute_uv=False)
    s_true = s_true[: fact.s.shape[-1]]
    return float(jnp.max(jnp.abs(fact.s - s_true)) / s_true[0])


def _unbatched_sweep(reqs, spec, key):
    """One-request-at-a-time ``factorize`` over the full mix (tenant
    requests included, each solved cold — the untracked baseline)."""
    t0 = time.perf_counter()
    facts = []
    for i, r in enumerate(reqs):
        f = factorize(r.A, spec, key=jax.random.fold_in(key, i))
        jax.block_until_ready(f.s)
        facts.append(f)
    wall_ms = (time.perf_counter() - t0) * 1e3
    cold_iters = [int(f.iterations) for f, r in zip(facts, reqs)
                  if r.tenant is not None]
    return wall_ms, facts, cold_iters


def _batched_sweep(reqs, spec, key, shapes, *, max_batch: int,
                   window_ms: float):
    """The same mix through a fresh ``SolveServer`` (plan cache stays warm
    across servers — steady state), submitted **open-loop**: every request
    enters the queue as it arrives, results are gathered after.  That is
    the offered-load regime continuous batching exists for — a closed loop
    of blocking clients would idle the window timer on its own feedback
    (see ``launch.solve_serve.run_traffic`` for that interactive mode).
    """
    server = SolveServer(spec, max_batch=max_batch, window_ms=window_ms,
                         max_queue=4 * len(reqs) + 16, key=key)
    try:
        server.warmup(shapes)
        t0 = time.perf_counter()
        tickets = [server.submit(r.A, kind=r.kind, tenant=r.tenant)
                   for r in reqs]
        results = [t.result(timeout=300.0) for t in tickets]
        wall_ms = (time.perf_counter() - t0) * 1e3
        server.batcher.stop()
        stats = server.stats()
    finally:
        server.close()
    tenant_iters = [r.meta["iterations"] for r in results
                    if r.kind == "tenant" and r.meta["kind"] == "refine"]
    return wall_ms, results, tenant_iters, stats


def run(requests: int = REQUESTS, mixes=None, repeats: int = 3,
        rank: int = 8, zipf_a: float = ZIPF_A) -> dict:
    key = jax.random.PRNGKey(1234)
    records = []
    for label, shapes in (mixes or MIXES):
        spec = SVDSpec(method="fsvd", rank=rank)
        reqs = list(synthetic_stream(
            requests, shapes=shapes, zipf_a=zipf_a, rank=rank,
            tenants=TENANTS, tenant_fraction=TENANT_FRACTION, seed=7))
        _warm(spec, shapes, key)
        # one uncounted traffic replay per path: warms what static staging
        # cannot enumerate — tenant sessions' learned refine budgets and
        # drift measurement ops.  The SAME key drives the replay and the
        # measured reps so fresh servers re-learn identical (quantized)
        # budgets and the reps run fully staged (steady-state serving);
        # repeats then measure pure timing variance.
        _unbatched_sweep(reqs, spec, key)
        _batched_sweep(reqs, spec, key, shapes, max_batch=MAX_BATCH,
                       window_ms=WINDOW_MS)

        runs = []
        for rep in range(repeats):
            un_ms, un_facts, cold_iters = _unbatched_sweep(reqs, spec, key)
            bat_ms, bat_results, tenant_iters, stats = _batched_sweep(
                reqs, spec, key, shapes, max_batch=MAX_BATCH,
                window_ms=WINDOW_MS)
            runs.append((bat_ms, un_ms, cold_iters, tenant_iters, stats,
                         un_facts, bat_results))
        bat_ms, un_ms, cold_iters, tenant_iters, stats, un_facts, \
            bat_results = sorted(runs, key=lambda x: x[0])[len(runs) // 2]

        # accuracy gate on a sample of anonymous requests, both paths
        sample = [(i, r) for i, r in enumerate(reqs)
                  if r.tenant is None][:24]
        unbatched_err = max(_sigma_err(un_facts[i], r.A)
                            for i, r in sample)
        batched_err = max(_sigma_err(bat_results[i].value, r.A)
                          for i, r in sample)

        rec = {
            "mix": label, "requests": requests, "zipf_a": zipf_a,
            "rank": rank, "max_batch": MAX_BATCH,
            "window_ms": WINDOW_MS, "tenants": TENANTS,
            "batched_wall_ms": bat_ms, "unbatched_wall_ms": un_ms,
            "batched_rps": requests / (bat_ms / 1e3),
            "unbatched_rps": requests / (un_ms / 1e3),
            "p50_ms": stats["latency_ms"]["p50_ms"],
            "p99_ms": stats["latency_ms"]["p99_ms"],
            "bucket_hit_rate": stats["bucket_hit_rate"],
            "batch_histogram": stats["batch_histogram"],
            "batched_err": batched_err, "unbatched_err": unbatched_err,
            "tenant_iters": (sum(tenant_iters) / len(tenant_iters)
                             if tenant_iters else 0.0),
            "cold_iters": (sum(cold_iters) / len(cold_iters)
                           if cold_iters else 0.0),
        }
        rec["speedup"] = rec["unbatched_wall_ms"] / rec["batched_wall_ms"]
        rec["iter_ratio"] = rec["cold_iters"] / max(rec["tenant_iters"],
                                                    1e-9)
        records.append(rec)

    rows = [[r["mix"], r["requests"], f"{r['unbatched_rps']:.0f}",
             f"{r['batched_rps']:.0f}", f"{r['speedup']:.2f}x",
             f"{r['p50_ms']:.1f}", f"{r['p99_ms']:.1f}",
             f"{r['bucket_hit_rate']:.2f}",
             f"{r['cold_iters']:.0f}->{r['tenant_iters']:.1f}",
             f"{r['batched_err']:.1e}", f"{r['unbatched_err']:.1e}"]
            for r in records]
    print(fmt_table(["mix", "reqs", "1-by-1 rps", "batched rps", "speedup",
                     "p50 ms", "p99 ms", "hit", "GK iters", "bat err",
                     "seq err"], rows))
    clear_plan_cache()
    return {"schema": "serve/v1", "records": records}


if __name__ == "__main__":
    run()
