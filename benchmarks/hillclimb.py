"""§Perf hillclimb driver: re-lower a chosen cell under config variants and
report the roofline-term deltas against the baseline artifact.

    PYTHONPATH=src python -m benchmarks.hillclimb --cell gemma-7b/train_4k/single \
        --variant online_attn
    PYTHONPATH=src python -m benchmarks.hillclimb --list

Each variant is one hypothesis -> change pair from EXPERIMENTS.md §Perf; the
measured before/after terms are appended to artifacts/hillclimb/.
"""
# must precede any jax import (dry-run device count)
import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512")

import argparse   # noqa: E402
import json       # noqa: E402

from benchmarks import roofline   # noqa: E402

# variant name -> (ModelConfig overrides, run_cell kwargs)
VARIANTS: dict = {
    "baseline": ({}, {}),
    # memory-term levers (q_chunk sized so the (B_loc, Cq, H_loc, Ck) tile
    # fits the 16 MiB VMEM-residency threshold of the HBM model)
    "online_attn": ({"attn_impl": "online", "q_chunk": 512}, {}),
    "online_attn_256": ({"attn_impl": "online", "q_chunk": 256}, {}),
    "online_attn_128": ({"attn_impl": "online", "q_chunk": 128}, {}),
    "online_attn_32": ({"attn_impl": "online", "q_chunk": 32}, {}),
    "chunked_attn": ({"attn_impl": "chunked", "q_chunk": 512}, {}),
    "pin_acts": ({"pin_activations": True}, {}),
    "pin_remat_dots": ({"pin_activations": True, "remat_policy": "dots"}, {}),
    "pin_online": ({"pin_activations": True, "attn_impl": "online",
                    "q_chunk": 512}, {}),
    "remat_dots": ({"remat_policy": "dots"}, {}),
    "online_remat_dots": ({"attn_impl": "online", "q_chunk": 256,
                           "remat_policy": "dots"}, {}),
    "ce_chunk_512": ({"ce_chunk": 512}, {}),
    # decode levers
    "dus_cache": ({"cache_update": "dus"}, {}),
    "dus_online": ({"cache_update": "dus", "attn_impl": "online"}, {}),
    # collective levers
    "compressed_grads": ({}, {"compressed_grads": True}),
    "pin_compressed": ({"pin_activations": True},
                       {"compressed_grads": True}),
    "online_compressed": ({"attn_impl": "online", "q_chunk": 512},
                          {"compressed_grads": True}),
    # decode levers on top of pinning
    "pin_dus": ({"pin_activations": True, "cache_update": "dus"}, {}),
}


def run_variant(arch: str, shape: str, mesh: str, variant: str,
                out_dir: str = "artifacts/hillclimb") -> dict:
    from repro.launch.dryrun import run_cell
    overrides, kwargs = VARIANTS[variant]
    multi = mesh in ("multi", "pod2x16x16")
    tag = f"{arch}_{shape}_{'pod2x16x16' if multi else 'pod16x16'}_{variant}"
    os.makedirs(out_dir, exist_ok=True)
    rec = run_cell(arch, shape, multi, cfg_overrides=overrides,
                   save_hlo_to=os.path.join(out_dir, "hlo", tag + ".hlo.gz"),
                   **kwargs)
    rec["variant"] = variant
    rec["overrides"] = overrides
    with open(os.path.join(out_dir, tag + ".json"), "w") as f:
        json.dump(rec, f, indent=1)
    return rec


def report(rec: dict, base: dict | None = None) -> None:
    t = roofline.terms(rec)
    print(f"\n[{rec['arch']} {rec['shape']} {rec['mesh']} "
          f"variant={rec.get('variant', '?')}]")
    if t is None:
        print("  status:", rec.get("status"), rec.get("error", "")[:300])
        return
    print(f"  compute    {t['compute_s']*1e3:10.1f} ms")
    print(f"  memory     {t['memory_s']*1e3:10.1f} ms")
    print(f"  collective {t['collective_s']*1e3:10.1f} ms")
    print(f"  dominant: {t['dominant']}   roofline frac: "
          f"{t['roofline_frac']*100:.1f}%")
    if base is not None:
        tb = roofline.terms(base)
        if tb:
            for k in ("compute_s", "memory_s", "collective_s"):
                b, n = tb[k], t[k]
                if b > 0:
                    print(f"  {k:12s} delta: {100*(n-b)/b:+.1f}%")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", required=False,
                    help="arch/shape/mesh, e.g. gemma-7b/train_4k/single")
    ap.add_argument("--variant", default="baseline",
                    choices=sorted(VARIANTS))
    ap.add_argument("--list", action="store_true")
    args = ap.parse_args()
    if args.list or not args.cell:
        for name, (ov, kw) in VARIANTS.items():
            print(f"{name:22s} overrides={ov} kwargs={kw}")
        return
    arch, shape, mesh = args.cell.split("/")
    base = None
    base_path = os.path.join(
        "artifacts/dryrun",
        f"{arch}_{shape}_{'pod2x16x16' if mesh == 'multi' else 'pod16x16'}"
        ".json")
    if os.path.exists(base_path):
        base = json.load(open(base_path))
    rec = run_variant(arch, shape, mesh, args.variant)
    report(rec, base)


if __name__ == "__main__":
    main()
