"""Zero-iteration rank-k update vs tracked refine vs cold factorize.

The PR 7 acceptance bench: a stream of *structured* drifts ``A_{t+1} =
A_t + U_t diag(s_t) Vt_t`` (rank-k, exactly the regime ROADMAP's
incremental-updates item names).  Three arms solve the identical stream:

* **cold** — per-step ``factorize`` of the drifted operand (full Krylov
  budget; shares the plan compile cache, so the comparison isolates
  algorithmic cost).
* **refine** — ``Session`` with ``update_tol=0.0``: the update path
  disabled, so every delta folds into the operand and runs the PR 5
  warm-started refine solve (reduced GK budget).
* **update** — ``Session`` with the default learned gate: every delta
  takes the rank-k Brand update (``repro.core.update``) — **zero** GK
  iterations, O((m+n)(r+k)^2) instead of O(iters * m * n).

All three arms are held to the same accuracy gate (max singular-value
error vs dense SVD of the true drifted matrix), so
``update ≫ refine ≫ cold`` is a like-for-like wall-time claim.

Section schema ``update/v1`` (validated by ``benchmarks.reanalyze``):
records carry raw timings/iterations and the re-derivable ratios
``update_vs_refine``/``update_vs_cold``/``refine_vs_cold``.

    PYTHONPATH=src python -m benchmarks.update_bench
    PYTHONPATH=src python -m benchmarks.run --only update --emit-json \
        BENCH_pr7.json
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from benchmarks.common import fmt_table, make_lowrank
from repro.api import LowRankOp, Session, SVDSpec, clear_plan_cache, \
    factorize

SIZES = [(512, 384, 8, 2), (1024, 512, 16, 4)]
QUICK_SIZES = [(256, 160, 8, 2)]

STEPS = 8          # structured drift steps per sweep
DRIFT = 1e-3       # per-step relative (Frobenius) drift


def _drift_stream(key, m: int, n: int, r: int, k: int, steps: int,
                  drift: float):
    """Exactly rank-r A_0, then ``steps`` cumulative rank-k deltas.

    Returns (operands, deltas): ``operands[t+1] = operands[t] +
    deltas[t]`` densified — the cold/refine arms consume the operands,
    the update arm consumes the deltas.
    """
    k0, kd = jax.random.split(key)
    A = make_lowrank(k0, m, n, r)
    operands, deltas = [A], []
    for t in range(steps):
        ku, kv = jax.random.split(jax.random.fold_in(kd, t))
        U = jax.random.normal(ku, (m, k))
        Vt = jax.random.normal(kv, (k, n))
        scale = drift * jnp.linalg.norm(A) / jnp.linalg.norm(U @ Vt)
        d = LowRankOp(U, jnp.full((k,), scale), Vt)
        A = A + (U * d.s) @ Vt
        deltas.append(d)
        operands.append(A)
    return ([jax.device_put(x) for x in operands],
            [jax.tree.map(jax.device_put, d) for d in deltas])


def _accuracy(fact, s_true) -> float:
    return float(jnp.max(jnp.abs(fact.s - s_true[: fact.rank]))
                 / s_true[0])


def _cold_sweep(operands, s_true, spec, key):
    """(total_ms, mean_iters, worst_err) for per-step cold factorize."""
    facts = []
    t0 = time.perf_counter()
    for t, A in enumerate(operands):
        f = factorize(A, spec, key=jax.random.fold_in(key, t))
        jax.block_until_ready(f.s)
        facts.append(f)
    ms = (time.perf_counter() - t0) * 1e3
    iters = sum(int(f.iterations) for f in facts) / len(facts)
    err = max(_accuracy(f, s) for f, s in zip(facts, s_true))
    return ms, iters, err


def _session_sweep(operands, deltas, s_true, spec, key, update_tol):
    """One Session over the stream: solve A_0 cold, then one delta()
    per step.  ``update_tol=0.0`` pins the refine arm (update disabled);
    ``None`` lets the gated update path engage."""
    sess = Session(operands[0], spec, key=key, track_residuals=False,
                   update_tol=update_tol)
    facts = []
    t0 = time.perf_counter()
    f = sess.solve()
    jax.block_until_ready(f.s)
    facts.append(f)
    for d in deltas:
        f = sess.delta(d)
        jax.block_until_ready(f.s)
        facts.append(f)
    ms = (time.perf_counter() - t0) * 1e3
    iters = sum(r["iterations"] for r in sess.history) / len(sess.history)
    err = max(_accuracy(f, s) for f, s in zip(facts, s_true))
    return ms, iters, err, sess.counts()


def run(sizes=None, repeats: int = 3, steps: int = STEPS,
        drift: float = DRIFT) -> dict:
    key = jax.random.PRNGKey(7)
    records = []
    for m, n, r, k in (sizes or SIZES):
        spec = SVDSpec(method="fsvd", rank=r)
        operands, deltas = _drift_stream(jax.random.fold_in(key, m * n),
                                         m, n, r, k, steps, drift)
        s_true = [jnp.linalg.svd(A, compute_uv=False) for A in operands]
        # one uncounted warm sweep per arm stages every executable (cold
        # budget, refine budget, update) — the measurement then isolates
        # solve cost, exactly like session_bench.  The update arm warms
        # two deltas: the first traces against the cold-solve fact
        # (method="fsvd"), the second against an update-produced fact
        # (method="update"); both executables must be staged.
        _cold_sweep(operands[:2], s_true[:2], spec, key)
        _session_sweep(operands[:3], deltas[:2], s_true[:3], spec, key, 0.0)
        _session_sweep(operands[:3], deltas[:2], s_true[:3], spec, key,
                       None)
        cold_runs, refine_runs, update_runs = [], [], []
        for rep in range(repeats):
            cold_runs.append(_cold_sweep(
                operands, s_true, spec, jax.random.fold_in(key, rep)))
            refine_runs.append(_session_sweep(
                operands, deltas, s_true, spec,
                jax.random.fold_in(key, 100 + rep), 0.0))
            update_runs.append(_session_sweep(
                operands, deltas, s_true, spec,
                jax.random.fold_in(key, 200 + rep), None))
        cold_ms, cold_iters, cold_err = \
            sorted(cold_runs)[len(cold_runs) // 2]
        refine_ms, refine_iters, refine_err, _ = sorted(
            refine_runs, key=lambda x: x[0])[len(refine_runs) // 2]
        update_ms, update_iters, update_err, counts = sorted(
            update_runs, key=lambda x: x[0])[len(update_runs) // 2]
        records.append({
            "m": m, "n": n, "rank": r, "k_drift": k, "steps": steps,
            "drift": drift,
            "cold_ms": cold_ms, "refine_ms": refine_ms,
            "update_ms": update_ms,
            "cold_iters": cold_iters, "refine_iters": refine_iters,
            "update_iters": update_iters,
            "cold_err": cold_err, "refine_err": refine_err,
            "update_err": update_err,
            "updates": counts.get("update", 0),
            "update_vs_refine": refine_ms / update_ms,
            "update_vs_cold": cold_ms / update_ms,
            "refine_vs_cold": cold_ms / refine_ms,
        })
    rows = [[f"{r['m']}x{r['n']}", r["rank"], r["k_drift"], r["steps"],
             f"{r['cold_ms']:.1f}", f"{r['refine_ms']:.1f}",
             f"{r['update_ms']:.1f}", f"{r['update_vs_refine']:.2f}x",
             f"{r['update_vs_cold']:.2f}x",
             f"{r['cold_err']:.1e}", f"{r['update_err']:.1e}"]
            for r in records]
    print(fmt_table(["shape", "r", "k", "steps", "cold ms", "refine ms",
                     "update ms", "upd/refine", "upd/cold",
                     "cold err", "update err"], rows))
    clear_plan_cache()
    return {"schema": "update/v1", "records": records}


if __name__ == "__main__":
    run()
