"""Paper Figure 2: RSGD similarity learning — wall time + accuracy with the
F-SVD retraction ("lower iter" k=20 / "higher iter" k=35) vs dense-SVD
retraction.  Synthetic MNIST/USPS-like domains (d1=784, d2=256, rank 5)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import fmt_table
from repro.core import manifold as mf
from repro.core import rsgd
from repro.core.fsvd import fsvd as _fsvd
from repro.data.synthetic import make_rsl_dataset, rsl_batch

D1, D2, RANK = 2048, 1024, 5
STEPS = 100           # dense-SVD baseline costs ~2 s/step at this size
BATCH = 64
LR = 3.0


def _make_dense_svd_step(opts):
    """Alg 4 with a dense-SVD retraction (the paper's baseline), jitted."""
    def step(W, Xb, Vb, y, key):
        bg = rsgd.batch_euclidean_grad(W, Xb, Vb, y, opts.loss,
                                       opts.weight_decay)
        xi = mf.project_tangent(W, bg.op)
        dense = mf.to_dense(W) - opts.lr * mf.tangent_to_dense(W, xi)
        U, s, Vt = jnp.linalg.svd(dense, full_matrices=False)
        return mf.FixedRankPoint(U[:, :RANK], s[:RANK], Vt[:RANK].T), bg.loss
    return jax.jit(step)


def _train(step_fn, ds, seed=0, steps=STEPS):
    W = mf.random_point(jax.random.PRNGKey(seed), D1, D2, RANK)
    losses = []
    key = jax.random.PRNGKey(seed + 1)
    # warmup/compile outside the timed loop
    b = rsl_batch(ds, seed, 0, BATCH)
    jax.block_until_ready(step_fn(W, b["x"], b["v"], b["y"], key))
    t0 = time.perf_counter()
    for t in range(steps):
        b = rsl_batch(ds, seed, t, BATCH)
        W, loss = step_fn(W, b["x"], b["v"], b["y"],
                          jax.random.fold_in(key, t))
        losses.append(float(loss))
    jax.block_until_ready(W)
    dt = time.perf_counter() - t0
    acc = float(rsgd.accuracy(W, ds.X, ds.V, ds.y))
    return dt, acc, losses


def run(steps=STEPS) -> dict:
    ds = make_rsl_dataset(jax.random.PRNGKey(1), 4096, D1, D2, RANK,
                          noise=0.05)
    rows = []
    for name, step_fn in [
        ("dense SVD", _make_dense_svd_step(rsgd.RSGDOptions(lr=LR))),
        ("F-SVD lower iter (k=20)",
         rsgd.make_step(rsgd.RSGDOptions(lr=LR, fsvd_iters=20))),
        ("F-SVD higher iter (k=35)",
         rsgd.make_step(rsgd.RSGDOptions(lr=LR, fsvd_iters=35))),
    ]:
        dt, acc, losses = _train(step_fn, ds, steps=steps)
        rows.append([name, f"{dt:.2f}", f"{acc*100:.1f}%",
                     f"{losses[0]:.3f}", f"{np.mean(losses[-10:]):.3f}"])
    print(f"\n## Figure 2 — RSGD similarity learning ({steps} steps, "
          f"W: {D1}x{D2} rank {RANK}, all retractions jitted)")
    print(fmt_table(["retraction", "time (s)", "accuracy", "loss[0]",
                     "loss[end]"], rows))
    return {"fig2": rows}


if __name__ == "__main__":
    run()
