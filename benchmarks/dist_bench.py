"""Sharded-solver scaling sweep: the perf trajectory grows a device axis.

For each device count in {1, 2, 4, 8} a fresh subprocess forces that many
host platform devices (``--xla_force_host_platform_device_count``), lays a
dense operand out row-sharded, and times

  * one fused ``lanczos_step`` / ``lanczos_rstep`` (the one-psum-per-half-
    step seam this PR adds — the unit of communication at scale), and
  * a full in-graph ``method="fsvd_sharded"`` solve,

all jitted, via the shared ``benchmarks.common.timeit``.  On forced *host*
devices the shards share one CPU, so wall-clock does not improve with the
device count — the records exist to (a) pin the collective structure cost
as overhead-per-rendezvous and (b) give real meshes a schema to report
into: each record carries a ``devices`` field, and ``benchmarks.reanalyze``
re-derives the ``*_vs_1dev`` ratios from the raw timings.

    PYTHONPATH=src python -m benchmarks.run --only dist --emit-json \\
        BENCH_pr4.json                       # the PR-4 scaling artifact
    PYTHONPATH=src python -m benchmarks.dist_bench            # standalone

Section schema ``dist/v1``: ``{"schema", "backend", "interpret", "passes",
"records": [{"devices", "m", "n", "k", "rank", "step_ms", "rstep_ms",
"solve_ms", "step_vs_1dev", "solve_vs_1dev"}]}``.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys

SIZES = [(4096, 1024, 64)]
QUICK_SIZES = [(512, 256, 16)]
DEVICE_COUNTS = (1, 2, 4, 8)
PASSES = 2
RANK = 8


def _worker(devices: int, sizes, repeats: int) -> None:
    """Runs inside the subprocess: time the fused seam on ``devices``.

    The sweep is a *host-device* sweep by construction (the flag below
    only multiplies CPU devices), so pin the platform to cpu unless the
    caller explicitly chose one — otherwise an accelerator machine would
    select its 1 GPU/TPU and the mesh construction would fail."""
    os.environ["XLA_FLAGS"] = (
        f"--xla_force_host_platform_device_count={devices}")
    os.environ.setdefault("JAX_PLATFORMS", "cpu")
    import jax
    import jax.numpy as jnp

    from benchmarks.common import timeit
    from repro.api import SVDSpec, factorize_jit
    from repro.distributed.matvec import sharded_operator
    from repro.launch.mesh import make_mesh
    import repro.distributed.gk_dist  # noqa: F401  (registers fsvd_sharded)

    mesh = make_mesh((devices,), ("data",))
    records = []
    for m, n, k in sizes:
        ks = jax.random.split(jax.random.PRNGKey(m + n + k), 5)
        A = jax.random.normal(ks[0], (m, n))
        op = sharded_operator(A, mesh)
        p = jax.random.normal(ks[1], (n,))
        q = jax.random.normal(ks[2], (m,))
        Q = jnp.linalg.qr(jax.random.normal(ks[3], (m, k)))[0]
        Pb = jnp.linalg.qr(jax.random.normal(ks[4], (n, k)))[0]

        step = jax.jit(lambda p, q, Q: op.lanczos_step(p, q, 0.4, Q,
                                                       passes=PASSES))
        rstep = jax.jit(lambda q, p, Pb: op.lanczos_rstep(q, p, 0.2, Pb,
                                                          passes=PASSES))
        ts, _ = timeit(step, p, q, Q, repeats=repeats)
        tr, _ = timeit(rstep, q, p, Pb, repeats=repeats)

        # factorize_jit: one compiled executable, so the timing is solve
        # execution (matvecs + psums), not per-call facade tracing.
        spec = SVDSpec(method="fsvd_sharded", rank=RANK,
                       max_iters=min(4 * RANK, k))
        solve = factorize_jit(spec, donate_q1=False)
        tsolve, _ = timeit(solve, op, jax.random.PRNGKey(0), None,
                           repeats=max(repeats - 1, 1))
        records.append({"devices": devices, "m": m, "n": n, "k": k,
                       "rank": RANK, "step_ms": ts * 1e3,
                        "rstep_ms": tr * 1e3, "solve_ms": tsolve * 1e3})
    print(json.dumps({"backend": jax.default_backend(),
                      "records": records}))


def run(sizes=None, devices=DEVICE_COUNTS, repeats: int = 3,
        quick: bool = False) -> dict:
    """Spawn one forced-device-count subprocess per entry and aggregate."""
    from benchmarks.common import fmt_table

    sizes = sizes if sizes is not None else (QUICK_SIZES if quick else SIZES)
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    env["PYTHONPATH"] = os.path.join(repo, "src") + os.pathsep + repo

    records = []
    backend = None
    for d in devices:
        payload = json.dumps({"devices": d, "sizes": sizes,
                              "repeats": repeats})
        out = subprocess.run(
            [sys.executable, "-m", "benchmarks.dist_bench", "--worker",
             payload],
            capture_output=True, text=True, env=env, cwd=repo, timeout=1800)
        if out.returncode != 0:
            raise RuntimeError(
                f"dist_bench worker (devices={d}) failed:\n"
                f"{out.stderr[-2000:]}")
        got = json.loads(out.stdout.strip().splitlines()[-1])
        backend = got["backend"]
        records.extend(got["records"])

    base = {(r["m"], r["n"], r["k"]): r for r in records
            if r["devices"] == 1}
    rows = []
    for r in records:
        b = base.get((r["m"], r["n"], r["k"]))
        r["step_vs_1dev"] = b["step_ms"] / r["step_ms"] if b else None
        r["solve_vs_1dev"] = b["solve_ms"] / r["solve_ms"] if b else None
        rows.append([f"{r['m']}x{r['n']} k={r['k']}", r["devices"],
                     f"{r['step_ms']:.2f}", f"{r['rstep_ms']:.2f}",
                     f"{r['solve_ms']:.1f}",
                     f"{r['step_vs_1dev']:.2f}x" if b else "-",
                     f"{r['solve_vs_1dev']:.2f}x" if b else "-"])
    print("\n## Sharded solver scaling (forced host devices; ratios are "
          "rendezvous-overhead probes on CPU, scaling on real meshes)")
    print(fmt_table(["shape", "devices", "step ms", "rstep ms", "solve ms",
                     "step vs 1dev", "solve vs 1dev"], rows))
    return {"schema": "dist/v1", "backend": backend,
            "interpret": backend != "tpu", "passes": PASSES,
            "records": records}


if __name__ == "__main__":
    if len(sys.argv) > 1 and sys.argv[1] == "--worker":
        cfg = json.loads(sys.argv[2])
        _worker(cfg["devices"], [tuple(s) for s in cfg["sizes"]],
                cfg["repeats"])
    else:
        run(quick="--quick" in sys.argv)
